"""Production observability plane (ISSUE 8): OpenMetrics exposition +
strict parser, the HTTP scrape endpoint, the flight recorder's ring and
trigger paths, event-log semantics, burn-rate window math under synthetic
schedules, and the tracer's registry gauges."""
import json
import urllib.request

import pytest

from repro.obs.events import EventLog
from repro.obs.export import (CONTENT_TYPE, ObsHTTPServer, OpenMetricsError,
                              escape_label_value, find_samples,
                              parse_openmetrics, render_openmetrics,
                              sanitize_name)
from repro.obs.flight import FlightRecorder
from repro.obs.metrics import MetricsRegistry, labeled, parse_labels
from repro.obs.slo import BurnRateTracker
from repro.obs.trace import Tracer


@pytest.fixture()
def reg():
    return MetricsRegistry()


@pytest.fixture()
def events(reg):
    return EventLog(registry=reg, tracer=Tracer(registry=reg))


# --------------------------------------------------------------- label plumbing
def test_parse_labels_round_trips_the_mangling_convention():
    name = labeled("serve.requests", {"model": "vgg16"})
    assert name == "serve.requests{model=vgg16}"
    assert parse_labels(name) == ("serve.requests", {"model": "vgg16"})
    assert parse_labels("plain") == ("plain", {})


def test_registry_labelled_indexes_per_label_value(reg):
    reg.counter("serve.rejected", {"model": "a"}).inc(2)
    reg.counter("serve.rejected", {"model": "b"}).inc(5)
    reg.counter("serve.rejected").inc()            # unlabelled variant
    by_model = reg.labelled("serve.rejected")
    assert by_model["a"].value == 2.0
    assert by_model["b"].value == 5.0
    assert by_model[None].value == 1.0
    assert reg.labelled("no.such.family") == {}


# ----------------------------------------------------------------- exposition
def test_render_golden_document(reg):
    reg.counter("serve.requests", {"model": "vgg16"}).inc(3)
    reg.gauge("serve.queue_depth").set(2)
    h = reg.histogram("lat.ms", [1.0, 10.0])
    h.observe(0.5)
    h.observe(5.0)
    h.observe(50.0)
    text = render_openmetrics(reg)
    assert text == (
        "# TYPE lat_ms histogram\n"
        'lat_ms_bucket{le="1.0"} 1\n'
        'lat_ms_bucket{le="10.0"} 2\n'
        'lat_ms_bucket{le="+Inf"} 3\n'
        "lat_ms_sum 55.5\n"
        "lat_ms_count 3\n"
        "# TYPE serve_queue_depth gauge\n"
        "serve_queue_depth 2\n"
        "# TYPE serve_requests counter\n"
        'serve_requests_total{model="vgg16"} 3\n'
        "# EOF\n")


def test_render_parse_round_trip_preserves_labels(reg):
    reg.counter("x", {"model": 'we"ird\\name'}).inc()
    reg.gauge("g", {"model": "line\nbreak"}).set(1.5)
    fams = parse_openmetrics(render_openmetrics(reg))
    assert find_samples(fams, "x", model='we"ird\\name')[0][2] == 1.0
    assert find_samples(fams, "g", model="line\nbreak")[0][2] == 1.5


def test_rendered_histogram_buckets_are_cumulative_and_monotone(reg):
    h = reg.histogram("h", [1.0, 2.0, 4.0], labels={"model": "m"})
    for v in (0.5, 1.5, 1.6, 3.0, 99.0):
        h.observe(v)
    fams = parse_openmetrics(render_openmetrics(reg))   # parser enforces both
    buckets = [v for n, ls, v in fams["h"]["samples"] if n == "h_bucket"]
    assert buckets == [1.0, 3.0, 4.0, 5.0]              # running totals
    assert find_samples(fams, "h", model="m")           # labels survived


def test_name_sanitization_and_escaping():
    assert sanitize_name("serve.latency_ms") == "serve_latency_ms"
    assert sanitize_name("9lives") == "_lives"
    assert escape_label_value('a"b\\c\nd') == 'a\\"b\\\\c\\nd'


def test_conflicting_family_types_refused(reg):
    reg.counter("thing", {"model": "a"})
    snap = reg.snapshot()
    snap["thing"] = {"type": "gauge", "value": 1.0}     # same family, gauge
    with pytest.raises(ValueError, match="conflicting types"):
        render_openmetrics(snap)


@pytest.mark.parametrize("doc,match", [
    ("# TYPE x counter\nx_total 1\n", "EOF"),
    ("x_total 1\n# EOF\n", "no preceding # TYPE"),
    ("# TYPE x counter\n# TYPE x counter\n# EOF\n", "declared twice"),
    ("# TYPE x histogram\nx_bucket 1\n# EOF\n", "without 'le'"),
    ('# TYPE x histogram\nx_bucket{le="1.0"} 5\nx_bucket{le="+Inf"} 3\n'
     "# EOF\n", "not cumulative"),
    ('# TYPE x histogram\nx_bucket{le="2.0"} 1\nx_bucket{le="1.0"} 2\n'
     'x_bucket{le="+Inf"} 2\n# EOF\n', "not increasing"),
    ('# TYPE x histogram\nx_bucket{le="1.0"} 1\n# EOF\n', "end at \\+Inf"),
    ('# TYPE x histogram\nx_bucket{le="+Inf"} 2\nx_count 3\n# EOF\n',
     "!= _count"),
    ('# TYPE x counter\nx_total{model=unquoted} 1\n# EOF\n', "not quoted"),
    ("# EOF\n# EOF\n", "before end"),
])
def test_strict_parser_rejects_malformed_documents(doc, match):
    with pytest.raises(OpenMetricsError, match=match):
        parse_openmetrics(doc)


# -------------------------------------------------------------- HTTP endpoint
def test_http_endpoint_serves_the_whole_plane(reg, events):
    flight = FlightRecorder(capacity=4, registry=reg, events=events)
    reg.counter("serve.requests", {"model": "m"}).inc()
    flight.record(req_id=1, tenant="m", latency_s=0.01)
    events.emit("unit.test", "hello", answer=42)
    with ObsHTTPServer(reg, flight=flight, events=events) as http:
        with urllib.request.urlopen(http.url("/metrics")) as r:
            assert r.headers["Content-Type"] == CONTENT_TYPE
            fams = parse_openmetrics(r.read().decode())
        assert find_samples(fams, "serve_requests", model="m")
        assert fams["obs_scrapes"]["samples"][0][2] == 1.0   # scrape counted
        fl = json.loads(urllib.request.urlopen(
            http.url("/flight")).read().decode())
        assert fl["records"][0]["req_id"] == 1
        lines = urllib.request.urlopen(
            http.url("/events")).read().decode().splitlines()
        assert any(json.loads(ln)["kind"] == "unit.test" for ln in lines)
        snap = json.loads(urllib.request.urlopen(
            http.url("/snapshot")).read().decode())
        assert set(snap) == {"metrics", "flight", "events", "trace"}
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(http.url("/nope"))


def test_dump_cli_scrapes_and_validates(reg, events, tmp_path):
    from repro.obs import dump as obs_dump
    reg.counter("c").inc()
    with ObsHTTPServer(reg, events=events) as http:
        out = tmp_path / "snap.json"
        ejl = tmp_path / "events.jsonl"
        events.emit("dump.test")
        snap = obs_dump.main(["--url", http.url("/").rstrip("/"),
                              "--out", str(out),
                              "--events-jsonl", str(ejl)])
    assert snap["n_families"] >= 1
    assert json.loads(out.read_text())["scraped_from"].startswith("http://")
    assert any(json.loads(ln)["kind"] == "dump.test"
               for ln in ejl.read_text().splitlines())


# ------------------------------------------------------------ flight recorder
def test_flight_ring_is_bounded_and_evicts_oldest(reg, events):
    fr = FlightRecorder(capacity=3, registry=reg, events=events)
    for i in range(5):
        fr.record(req_id=i, tenant="m", latency_s=0.001)
    recs = fr.records()
    assert [r.req_id for r in recs] == [2, 3, 4]
    assert fr.n_recorded == 5
    assert reg.get("flight.records").value == 3.0


def test_flight_trigger_paths_and_rate_limit(reg, events, tmp_path):
    clock = FakeClock()
    fr = FlightRecorder(capacity=8, dump_dir=str(tmp_path), registry=reg,
                        events=events, min_interval_s=10.0, clock=clock)
    fr.set_context("m", slo_class="gold", target_p99_ms=10.0)
    # executor exception auto-dumps
    fr.record(req_id=1, tenant="m", status="error", error="Boom: x")
    # rejection records + dumps under its own reason
    fr.note_rejection("m", pending=9, bound=8)
    dumps = fr.dumps()
    assert [d["reason"] for d in dumps] == ["executor_exception",
                                            "admission_rejection"]
    assert dumps[0]["context"]["m"]["slo_class"] == "gold"
    assert dumps[1]["records"][-1]["status"] == "rejected"
    on_disk = sorted(p.name for p in tmp_path.iterdir())
    assert on_disk == ["flight-1-executor_exception.json",
                       "flight-2-admission_rejection.json"]
    # within min_interval_s the same reason is suppressed, others are not
    assert fr.trigger("executor_exception") is None
    assert reg.get("flight.dumps_suppressed").value == 1.0
    clock.t += 11.0
    assert fr.trigger("executor_exception") is not None
    # every dump emits a cross-referencing event
    kinds = [e.kind for e in events.records(kind="flight")]
    assert kinds == ["flight.dump"] * 3


def test_flight_bind_feeds_batcher_records_with_drift(reg, events):
    fr = FlightRecorder(capacity=4, registry=reg, events=events)
    state = {"aggregate": 0.2, "drifted": True}
    obs = fr.bind(tenant="m", drift_state=lambda: state)
    obs({"req_id": 7, "submit_s": 0.0, "queue_wait_s": 0.001,
         "execute_s": 0.002, "latency_s": 0.003, "batch_id": 1,
         "batch_size": 2, "batch_members": (7, 8), "status": "ok",
         "error": None})
    rec = fr.records()[-1]
    assert rec.tenant == "m" and rec.drift["drifted"] is True
    assert rec.batch_members == (7, 8)


# ------------------------------------------------------------------ event log
def test_event_log_severity_filter_capacity_and_span_correlation(reg):
    tr = Tracer(registry=reg)
    tr.enable()
    log = EventLog(capacity=3, registry=reg, tracer=tr)
    with pytest.raises(ValueError, match="unknown severity"):
        log.emit("x", severity="fatal")
    with tr.span("compiling", cat="test"):
        log.emit("inside", severity="debug")
    assert log.records()[-1].span == "compiling"
    log.emit("warn1", severity="warning")
    log.emit("err1", severity="error")
    log.emit("info1")                      # capacity 3: "inside" dropped
    assert len(log) == 3 and log.n_dropped == 1
    assert reg.get("events.dropped").value == 1.0
    assert [e.kind for e in log.records(min_severity="warning")] \
        == ["warn1", "err1"]
    assert reg.get("events.emitted{severity=warning}").value == 1.0
    # mirrored markers land on the trace's "events" track
    names = [s.name for s in tr.records() if s.track == "events"]
    assert set(names) >= {"inside", "warn1", "err1", "info1"}


def test_event_subscribers_are_notified_and_isolated(events):
    seen = []
    events.subscribe(lambda e: seen.append(e.kind))
    events.subscribe(lambda e: 1 / 0)      # broken subscriber is swallowed
    events.emit("tick")
    assert seen == ["tick"]
    events.unsubscribe(events._subs[1])
    events.emit("tock")
    assert seen == ["tick", "tock"]


def test_event_jsonl_round_trips(events, tmp_path):
    events.emit("a.b", "msg", severity="warning", n=3)
    path = events.to_jsonl(str(tmp_path / "ev.jsonl"))
    rec = json.loads(open(path).read().splitlines()[0])
    assert rec["kind"] == "a.b" and rec["fields"] == {"n": 3}
    assert rec["severity"] == "warning"


# ----------------------------------------------------------------- burn rates
class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def _tracker(reg, events, clock, **kw):
    kw.setdefault("budget", 0.01)
    kw.setdefault("fast_window_s", 30.0)
    kw.setdefault("slow_window_s", 300.0)
    kw.setdefault("alert_burn", 2.0)
    kw.setdefault("min_samples", 8)
    kw.setdefault("cooldown_s", 60.0)
    return BurnRateTracker(10.0, labels={"model": "m", "class": "gold"},
                           registry=reg, events=events, clock=clock, **kw)


def test_burn_rate_is_violation_fraction_over_budget(reg, events):
    clock = FakeClock()
    bt = _tracker(reg, events, clock)
    for i in range(10):                    # 2 of 10 violate -> 0.2/0.01 = 20x
        clock.t = float(i)
        bt.observe(100.0 if i < 2 else 1.0)
    rates = bt.burn_rates()
    assert rates["fast"] == pytest.approx(20.0)
    assert rates["slow"] == pytest.approx(20.0)
    assert rates["n_fast"] == 10
    g = reg.get("slo.burn_rate{class=gold,model=m,window=fast}")
    assert g is not None and g.value == pytest.approx(20.0)


def test_old_samples_age_out_of_the_fast_window(reg, events):
    clock = FakeClock()
    bt = _tracker(reg, events, clock, min_samples=4)
    for i in range(4):                     # all violations at t=0..3
        clock.t = float(i)
        bt.observe(100.0)
    clock.t = 100.0                        # fast window (30s) has moved on
    bt.observe(1.0)
    rates = bt.burn_rates()
    assert rates["n_fast"] == 1 and rates["fast"] == 0.0
    assert rates["n_slow"] == 5 and rates["slow"] > 0.0


def test_alert_requires_both_windows_min_samples_and_cooldown(reg, events):
    clock = FakeClock()
    bt = _tracker(reg, events, clock, min_samples=8, cooldown_s=60.0)
    fired = []
    bt.on_alert = lambda t, fast, slow: fired.append((fast, slow))
    # 7 violations: below min_samples, never fires
    for i in range(7):
        clock.t = float(i)
        assert not bt.observe(100.0)
    # 8th closes min_samples with both windows burning: fires once
    clock.t = 7.0
    assert bt.observe(100.0)
    assert bt.n_alerts == 1 and len(fired) == 1
    # still burning inside the cooldown: suppressed
    clock.t = 20.0
    assert not bt.observe(100.0)
    # keep the fast window populated; fires again once the cooldown passes
    for i in range(7):
        clock.t = 60.0 + i
        assert not bt.observe(100.0)       # n_fast < min_samples
    clock.t = 70.0
    assert bt.observe(100.0)
    assert bt.n_alerts == 2
    assert reg.get("slo.alerts{class=gold,model=m}").value == 2.0
    kinds = [e.kind for e in events.records(kind="slo")]
    assert kinds == ["slo.alert", "slo.alert"]
    assert events.records(kind="slo")[0].fields["model"] == "m"


def test_slow_window_vetoes_fast_transients(reg, events):
    clock = FakeClock()
    bt = _tracker(reg, events, clock, min_samples=4, cooldown_s=0.0)
    # long healthy history fills the slow window with zeros
    for i in range(200):
        clock.t = float(i)
        bt.observe(1.0)
    # a short burst of violations lights the fast window only
    for i in range(4):
        clock.t = 290.0 + i
        assert not bt.observe(100.0)       # slow window still diluted
    rates = bt.burn_rates()
    assert rates["fast"] >= 2.0                 # fast is hot...
    assert rates["slow"] < 2.0
    assert bt.n_alerts == 0                     # ...but nothing fired


def test_observer_skips_failed_requests(reg, events):
    clock = FakeClock()
    bt = _tracker(reg, events, clock)
    obs = bt.observer()
    obs({"status": "error", "latency_s": 9.9})
    assert bt.n_observed == 0
    obs({"status": "ok", "latency_s": 0.001})
    assert bt.n_observed == 1 and bt.n_violations == 0


def test_on_alert_exceptions_are_swallowed(reg, events):
    clock = FakeClock()
    bt = _tracker(reg, events, clock, min_samples=2, cooldown_s=0.0)
    bt.on_alert = lambda *a: 1 / 0
    clock.t = 0.0
    bt.observe(100.0)
    clock.t = 1.0
    assert bt.observe(100.0)               # alert fired despite broken hook


# --------------------------------------------------------------- tracer gauges
def test_tracer_exports_ring_occupancy_and_drop_gauges():
    reg = MetricsRegistry()
    tr = Tracer(capacity=2, registry=reg)
    tr.enable()
    for i in range(5):
        with tr.span(f"s{i}", cat="test"):
            pass
    assert reg.get("trace.spans").value == 2.0
    assert reg.get("trace.dropped").value == 3.0
    assert tr.n_dropped == 3
    tr.clear()
    assert reg.get("trace.spans").value == 0.0
    assert reg.get("trace.dropped").value == 0.0
