"""Algorithm 2 (execution path search) invariants (paper C3)."""
import itertools
import math

import pytest

pytest.importorskip("hypothesis")  # dev-only dep (requirements-dev.txt)
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core import pathsearch
from repro.core.cost import AnalyticEvaluator
from repro.core.xgraph import XGraph
from repro.core import frontend
from repro.hw import ZU2, ZU9
from tests.conftest import make_toy_resnet_graph


def test_cover_exactly_once():
    g = make_toy_resnet_graph()
    for fn in (pathsearch.naive, pathsearch.greedy, pathsearch.search):
        s = fn(g, ZU2)
        plannable = {n.name for n in g if n.op != "input"}
        assert s.covered() == plannable
        seen = [nm for grp in s.groups + s.horizontal for nm in grp]
        assert len(seen) == len(set(seen)), "node fused twice"


def test_cost_ordering():
    """optimized <= greedy <= naive under the same evaluator."""
    g = make_toy_resnet_graph()
    ev = AnalyticEvaluator(g, ZU2)
    n = pathsearch.naive(g, ZU2, evaluator=ev)
    gr = pathsearch.greedy(g, ZU2, evaluator=ev)
    opt = pathsearch.search(g, ZU2, evaluator=ev)
    assert opt.cost <= gr.cost + 1e-12
    assert gr.cost <= n.cost + 1e-12


def _chain_graph(lengths):
    g = XGraph()
    g.input("x", (1, 32, 32, 8))
    last = "x"
    for i, oc in enumerate(lengths):
        g.add("conv", f"c{i}", (last,), oc=oc, kernel=(3, 3), pad="same")
        last = f"c{i}"
    return g


@settings(max_examples=20, deadline=None)
@given(st.lists(st.sampled_from([8, 16, 32]), min_size=2, max_size=6))
def test_chain_partition_optimal_vs_bruteforce(ocs):
    """Floyd chain partition == brute-force best over all cut subsets."""
    g = _chain_graph(ocs)
    frontend.lower(g)
    ev = AnalyticEvaluator(g, ZU9)
    from repro.core import isomorphism, templates
    pairs = templates.pairwise_fusable(
        isomorphism.find_all(g, templates.KERNEL_TEMPLATES))
    chain = [f"c{i}" for i in range(len(ocs))]
    segs, cost = pathsearch.partition_chain(g, chain, pairs, ev)
    # brute force: every composition of the chain into valid segments
    best = math.inf
    m = len(chain)
    for cuts in itertools.product([0, 1], repeat=m - 1):
        pieces, cur = [], [chain[0]]
        for i, c in enumerate(cuts):
            if c:
                pieces.append(cur)
                cur = []
            cur.append(chain[i + 1])
        pieces.append(cur)
        tot = 0.0
        ok = True
        for p in pieces:
            if len(p) > 1 and not all((p[i], p[i + 1]) in pairs
                                      for i in range(len(p) - 1)):
                ok = False
                break
            c = ev(p)
            if not math.isfinite(c):
                ok = False
                break
            tot += c
        if ok:
            best = min(best, tot)
    assert abs(cost - best) < 1e-12


def test_barriers_respected():
    """Fusion never crosses a fork/merge except the enumerated eltwise /
    horizontal cases (paper §5.2)."""
    g = make_toy_resnet_graph()
    s = pathsearch.search(g, ZU2)
    for grp in s.groups:
        for a, b in zip(grp, grp[1:]):
            assert a in g.nodes[b].inputs, f"non-adjacent fused {a},{b}"
            # interior producers must have out-degree 1 (or be the eltwise
            # absorption case where b is the merge itself)
            if g.nodes[b].op != "eltwise_add":
                assert len(g.consumers(a)) == 1


def test_eltwise_absorbed_into_branch():
    g = make_toy_resnet_graph()
    s = pathsearch.search(g, ZU2)
    fused_elt = [grp for grp in s.groups if "add1" in grp and len(grp) > 1]
    assert fused_elt, "conv+eltwise fusion opportunity missed"


def test_horizontal_at_fork():
    g = make_toy_resnet_graph()
    s = pathsearch.search(g, ZU2)
    assert any(set(h) >= {"c2a", "c2s"} or set(h) >= {"c2s", "c2a"}
               for h in s.horizontal), s.horizontal
