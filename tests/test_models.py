"""Per-architecture smoke tests (assignment requirement f): reduced config,
one forward/train step on CPU, shape + no-NaN asserts, decode consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import api


def _batch(cfg, rng, B=2, S=64):
    if cfg.family == "audio":
        return {"frames": jnp.asarray(
                    rng.standard_normal((B, 32, cfg.d_model)), jnp.float32),
                "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, 32)),
                                      jnp.int32),
                "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, 32)),
                                      jnp.int32)}
    if cfg.family == "vlm":
        st = S - cfg.n_patches
        return {"patch_embeds": jnp.asarray(
                    rng.standard_normal((B, cfg.n_patches, cfg.d_model)),
                    jnp.float32),
                "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, st)),
                                      jnp.int32),
                "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, st)),
                                      jnp.int32)}
    return {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)}


@pytest.mark.parametrize("arch", sorted(configs.ARCHS))
def test_smoke_forward_and_decode(arch):
    cfg = configs.get(arch).smoke()
    rng = np.random.default_rng(1)
    params = api.init_params(cfg)
    batch = _batch(cfg, rng)
    loss = jax.jit(lambda p, b: api.loss_fn(cfg, p, b))(params, batch)
    assert np.isfinite(float(loss)), f"{arch}: loss not finite"
    B = 2
    cache = api.init_cache(cfg, B, 128)
    logits, cache2 = jax.jit(
        lambda p, c, t, pos: api.decode_step(cfg, p, c, t, pos))(
        params, cache, batch["tokens"][:, 0], jnp.int32(0))
    assert logits.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    # cache must actually change
    changed = any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(cache), jax.tree.leaves(cache2)))
    assert changed, f"{arch}: decode did not update its cache/state"


@pytest.mark.parametrize("arch", ["granite-8b", "mixtral-8x7b", "xlstm-1.3b",
                                  "zamba2-1.2b"])
def test_decode_matches_prefill(arch):
    """Teacher-forced decode logits == forward logits at each position."""
    cfg = dataclasses.replace(configs.get(arch).smoke(), remat=False)
    rng = np.random.default_rng(5)
    params = api.init_params(cfg)
    S = 16
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (1, S)), jnp.int32)
    if cfg.family in ("dense", "moe", "vlm"):
        from repro.nn import model as m

        full_logits, _ = m.forward(cfg, params, toks)
    elif cfg.family == "ssm":
        from repro.nn import xlstm as m

        full_logits, _ = m.forward(cfg, params, toks)
    else:
        from repro.nn import zamba as m

        full_logits, _ = m.forward(cfg, params, toks)
    cache = api.init_cache(cfg, 1, S)
    outs = []
    for t in range(S):
        lg, cache = api.decode_step(cfg, params, cache, toks[:, t],
                                    jnp.int32(t))
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)  # (1, S, V)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(full_logits, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_swa_decode_rolls_over_window():
    """Mixtral-style sliding window: decoding past the window must keep
    working (rolling cache) and only attend to the last `window` tokens."""
    cfg = configs.get("mixtral-8x7b").smoke()
    assert cfg.window == 64
    cfg = dataclasses.replace(cfg, window=8, remat=False)
    rng = np.random.default_rng(9)
    params = api.init_params(cfg)
    cache = api.init_cache(cfg, 1, 64)
    assert cache["k"].shape[2] == 8  # rolling buffer == window
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (20,)), jnp.int32)
    for t in range(20):
        lg, cache = api.decode_step(cfg, params, cache, toks[t][None],
                                    jnp.int32(t))
        assert np.isfinite(np.asarray(lg, np.float32)).all()


def test_param_counts_sane():
    """cfg.n_params should be within 20% of the actual initialized count."""
    for arch in ("granite-8b", "mixtral-8x7b", "xlstm-1.3b"):
        cfg = configs.get(arch)
        est = cfg.n_params
        # count abstract (no allocation)
        abs_p = api.abstract_params(cfg)
        real = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(abs_p))
        assert 0.7 < est / real < 1.4, (arch, est, real)
