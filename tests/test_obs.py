"""Observability layer (ISSUE 6 tentpole): span tracer semantics, Chrome
trace export, metrics registry, drift detection, and the serve-path split
timings the SLO controller consumes."""
import dataclasses
import json
import threading

import numpy as np
import pytest

from repro.obs import (REGISTRY, Counter, DriftProfiler, Gauge, Histogram,
                       MetricsRegistry, Tracer)
from repro.obs.metrics import DEFAULT_BATCH_BUCKETS


# ------------------------------------------------------------------- tracer
def test_span_nesting_and_track_inheritance():
    tr = Tracer(enabled=True)
    with tr.span("outer", cat="compile", track="compile"):
        with tr.span("inner"):
            pass
    recs = tr.records()
    assert [r.name for r in recs] == ["inner", "outer"]   # close order
    inner, outer = recs
    assert outer.depth == 0 and inner.depth == 1
    assert inner.track == "compile"                       # inherited
    assert outer.start <= inner.start <= inner.end <= outer.end


def test_span_records_timing_and_args():
    fake = iter([1.0, 2.5]).__next__
    tr = Tracer(enabled=True, clock=fake)
    with tr.span("work", cat="c", n=3) as sp:
        sp.set(extra="yes")
    (rec,) = tr.records()
    assert rec.start == 1.0 and rec.end == 2.5
    assert rec.duration == pytest.approx(1.5)
    assert rec.args == {"n": 3, "extra": "yes"}


def test_disabled_tracer_records_nothing():
    tr = Tracer(enabled=False)
    with tr.span("x"):
        pass
    tr.add_span("y", 0.0, 1.0)
    tr.instant("z")
    assert tr.add_engine_windows({"CONV": [(0, 10, "CONV", "t")]}, 1e6) == 0
    assert len(tr) == 0 and tr.n_recorded == 0
    # same shared no-op object every time: the hot path allocates nothing
    assert tr.span("a") is tr.span("b")


def test_ring_buffer_bounds_retention():
    tr = Tracer(capacity=16, enabled=True)
    for i in range(100):
        tr.add_span(f"s{i}", float(i), float(i) + 0.5)
    assert len(tr) == 16
    assert tr.n_recorded == 100
    assert tr.n_dropped == 84
    names = [r.name for r in tr.records()]
    assert names == [f"s{i}" for i in range(84, 100)]     # newest survive


def test_tracer_thread_safety():
    tr = Tracer(capacity=100_000, enabled=True)
    n_threads, n_spans = 8, 200

    def work(tid):
        for i in range(n_spans):
            with tr.span(f"t{tid}-{i}"):
                pass

    threads = [threading.Thread(target=work, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    recs = tr.records()
    assert len(recs) == n_threads * n_spans
    # per-thread tracks stay distinct and every span landed at depth 0
    assert all(r.depth == 0 for r in recs)
    assert len({r.track for r in recs}) == n_threads


def test_chrome_trace_schema():
    tr = Tracer(enabled=True)
    with tr.span("compile_stage", cat="compile", track="compile"):
        pass
    tr.add_span("queue_wait", 1.0, 2.0, cat="serve", track="req1")
    tr.add_engine_windows({"CONV": [(0, 100, "CONV", "c1@t0")]},
                          freq_hz=1e6, origin=0.0)
    doc = json.loads(json.dumps(tr.to_chrome()))          # JSON-serialisable
    evs = doc["traceEvents"]
    xs = [e for e in evs if e["ph"] == "X"]
    ms = [e for e in evs if e["ph"] == "M"]
    assert len(xs) == 3 and ms
    for e in xs:
        assert e["ts"] >= 0 and e["dur"] >= 0             # µs, non-negative
        assert isinstance(e["pid"], int) and isinstance(e["tid"], int)
    # every (pid, tid) used by an X event has a thread_name metadata row
    named = {(e["pid"], e["tid"]) for e in ms if e["name"] == "thread_name"}
    assert {(e["pid"], e["tid"]) for e in xs} <= named
    procs = {e["args"]["name"] for e in ms if e["name"] == "process_name"}
    assert {"measured", "modeled"} <= procs


def test_engine_windows_become_modeled_tracks():
    tr = Tracer(enabled=True)
    win = {"CONV": [(0, 100, "CONV", "c1@t0"), (150, 300, "CONV", "c2@t0")],
           "LOAD": [(0, 80, "LOAD", "c2@t0")]}
    n = tr.add_engine_windows(win, freq_hz=1e6, origin=10.0)
    assert n == 3
    recs = tr.records()
    assert {r.process for r in recs} == {"modeled"}
    assert {r.track for r in recs} == {"CONV", "LOAD"}
    conv = [r for r in recs if r.track == "CONV"][0]
    assert conv.start == pytest.approx(10.0)
    assert conv.duration == pytest.approx(100 / 1e6)
    assert conv.args["cycles"] == 100


# ------------------------------------------------------------------ metrics
def test_counter_and_gauge():
    reg = MetricsRegistry()
    c = reg.counter("c")
    c.inc()
    c.inc(2)
    assert c.value == 3
    with pytest.raises(ValueError):
        c.inc(-1)
    g = reg.gauge("g")
    g.set(5)
    g.dec(2)
    assert g.value == 3
    assert reg.counter("c") is c                          # get-or-create


def test_histogram_buckets_and_percentiles():
    h = Histogram("h", bounds=(1.0, 10.0, 100.0))
    for v in (0.5, 5.0, 50.0, 500.0):
        h.observe(v)
    snap = h.snapshot()
    assert snap["count"] == 4
    assert snap["buckets"] == {"1.0": 1, "10.0": 1, "100.0": 1, "+inf": 1}
    assert snap["min"] == 0.5 and snap["max"] == 500.0
    assert h.percentile(1.0) == 500.0                     # overflow -> max
    assert 0.0 < h.percentile(0.25) <= 1.0
    with pytest.raises(ValueError):
        Histogram("bad", bounds=(2.0, 1.0))


def test_registry_type_conflict_and_bound():
    reg = MetricsRegistry(max_metrics=2)
    reg.counter("a")
    with pytest.raises(TypeError):
        reg.gauge("a")
    reg.gauge("b")
    with pytest.raises(RuntimeError):
        reg.counter("c")


def test_snapshot_stable_and_json_serialisable():
    reg = MetricsRegistry()
    reg.counter("z.count").inc(7)
    reg.gauge("a.depth").set(2)
    h = reg.histogram("m.lat")
    for v in (1.0, 2.0, 3.0):
        h.observe(v)
    s1, s2 = reg.snapshot(), reg.snapshot()
    assert s1 == s2                                       # stable
    assert list(s1) == sorted(s1)                         # deterministic order
    json.dumps(s1)
    assert s1["z.count"] == {"type": "counter", "value": 7.0}
    assert s1["m.lat"]["count"] == 3
    assert "p50" in s1["m.lat"] and "p99" in s1["m.lat"]


def test_metrics_thread_safe_increments():
    reg = MetricsRegistry()
    c = reg.counter("n")
    h = reg.histogram("h", bounds=DEFAULT_BATCH_BUCKETS)

    def work():
        for _ in range(1000):
            c.inc()
            h.observe(1.0)

    threads = [threading.Thread(target=work) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.value == 8000
    assert h.count == 8000


# ----------------------------------------------------------- serve plumbing
def test_batcher_splits_queue_wait_from_execute():
    from repro.runtime.batching import DynamicBatcher

    with DynamicBatcher(lambda xs: [x + 1 for x in xs], max_batch=4,
                        max_latency_s=1e-3,
                        registry=MetricsRegistry()) as b:
        futs = [b.submit(i) for i in range(8)]
        assert [f.result() for f in futs] == [i + 1 for i in range(8)]
    assert len(b.latencies) == 8
    assert len(b.queue_waits) == 8                        # per request
    assert 1 <= len(b.execute_s) <= 8                     # per batch
    # wait + execute bound the end-to-end latency from below
    assert max(b.queue_waits) <= max(b.latencies) + 1e-9
    assert all(e >= 0 for e in b.execute_s)


def test_batcher_emits_serve_spans():
    from repro.runtime.batching import DynamicBatcher

    tr = Tracer(enabled=True)
    with DynamicBatcher(lambda xs: list(xs), max_batch=4, max_latency_s=1e-3,
                        registry=MetricsRegistry(), tracer=tr) as b:
        [f.result() for f in [b.submit(i) for i in range(4)]]
    names = {r.name for r in tr.records()}
    assert {"queue_wait", "execute", "batch_form", "batch_execute",
            "resolve"} <= names
    tracks = {r.track for r in tr.records()}
    assert "batch" in tracks
    assert any(t.startswith("req") for t in tracks)


def test_server_stats_carry_split_percentiles(toy_session):
    srv = toy_session.serve(max_batch=4, max_latency_s=1e-3, warmup=False)
    rng = np.random.default_rng(0)
    x = rng.integers(-128, 128, toy_session.graph.shape("data")[1:],
                     endpoint=False).astype(np.int8)
    [f.result() for f in [srv.submit(x) for _ in range(6)]]
    srv.close()
    st = srv.stats()
    assert st["queue_wait_p99_ms"] is not None
    assert st["execute_p99_ms"] is not None
    assert st["slo_shrinks_queue_bound"] == 0             # no SLO configured
    assert st["slo_shrinks_launch_bound"] == 0


# -------------------------------------------------------------------- drift
@pytest.fixture(scope="module")
def toy_session():
    from tests.conftest import make_toy_resnet_graph, toy_params
    from repro import asm
    from repro.core import executor, pathsearch, quantize
    from repro.core.cost import SimulatorEvaluator
    from repro.hw import ZU2
    from repro.runtime import Session
    from repro.tune import CalibratedEvaluator, calibrate

    g = make_toy_resnet_graph()
    params = toy_params(g)
    rng = np.random.default_rng(0)
    x = rng.standard_normal(g.shape("data")).astype(np.float32)
    qm = quantize.calibrate(g, params, x, executor.run_float)
    sim = SimulatorEvaluator(g, ZU2)
    res = calibrate(g, qm, ZU2, measure_fn=lambda grp: sim(grp),
                    features="analytic")
    p = res.profile
    s = pathsearch.search(g, ZU2, evaluator=CalibratedEvaluator(g, ZU2, p))
    return Session(g, s, ZU2, qm, backend="pallas", cache=asm.PlanCache(),
                   profile=p)


def _prediction_fn(session):
    """measure_fn that returns exactly the profile's own predictions — an
    undrifted world, deterministic."""
    from repro.tune.evaluator import predict_item_seconds

    p = session.profile
    return lambda item: predict_item_seconds(p, session.graph,
                                             session.device, item)


def test_drift_unperturbed_within_band(toy_session):
    dp = DriftProfiler.from_session(toy_session, every=1,
                                    measure_fn=_prediction_fn(toy_session),
                                    registry=MetricsRegistry())
    dp.sample()
    rep = dp.report()
    assert rep.units and not rep.skipped
    assert rep.aggregate == pytest.approx(0.0, abs=1e-12)
    assert rep.aggregate <= rep.calibration_band[1]       # inside 5-10% band
    assert rep.profile_match
    assert not rep.drifted
    json.dumps(rep.to_json())


def test_drift_perturbed_profile_flagged(toy_session):
    p2 = dataclasses.replace(
        toy_session.profile,
        coef=tuple(2 * c for c in toy_session.profile.coef))
    dp = DriftProfiler(toy_session.graph, toy_session.qm,
                       toy_session.artifact, toy_session.device, p2, every=1,
                       measure_fn=_prediction_fn(toy_session),
                       registry=MetricsRegistry())
    dp.sample()
    rep = dp.report()
    # predictions doubled, measurements unchanged -> 50% deviation
    assert rep.aggregate == pytest.approx(0.5, abs=1e-9)
    assert rep.aggregate > rep.band
    assert not rep.profile_match                          # hash moved too
    assert rep.drifted


def test_drift_sampling_cadence(toy_session):
    calls = []

    def fake_measure(item):
        calls.append(item)
        return 1e-3

    dp = DriftProfiler.from_session(toy_session, every=4,
                                    measure_fn=fake_measure,
                                    registry=MetricsRegistry())
    n_units = len(dp._resolve_units())
    fired = [dp.observe_launch() for _ in range(8)]
    assert fired == [False, False, False, True] * 2       # every 4th
    assert len(calls) == 2 * n_units
    assert dp.n_observed == 8 and dp.n_sampled == 2


def test_drift_gauges_are_labelled_per_model(toy_session):
    """ISSUE 8 satellite: drift reports land as per-model gauges
    (``drift.median_deviation{model=}``, ``drift.tripped{model=}``) and the
    first False->True transition emits one ``drift.trip`` event."""
    reg = MetricsRegistry()
    p2 = dataclasses.replace(
        toy_session.profile,
        coef=tuple(2 * c for c in toy_session.profile.coef))
    dp = DriftProfiler(toy_session.graph, toy_session.qm,
                       toy_session.artifact, toy_session.device, p2, every=1,
                       measure_fn=_prediction_fn(toy_session),
                       registry=reg, labels={"model": "toy"})
    # the trip event goes through the shared log; watch it via a subscriber
    from repro.obs.events import EVENTS
    trips = []
    watch = lambda e: trips.append(e) if e.kind == "drift.trip" else None
    EVENTS.subscribe(watch)
    try:
        dp.sample()
        dp.sample()                       # still drifted: no second event
    finally:
        EVENTS.unsubscribe(watch)
    assert reg.get("drift.median_deviation{model=toy}").value \
        == pytest.approx(0.5, abs=1e-9)
    assert reg.get("drift.tripped{model=toy}").value == 1.0
    assert reg.get("drift.samples{model=toy}").value == 2.0
    assert len(trips) == 1
    assert trips[0].fields["model"] == "toy"
    # the cached summary the flight recorder stamps onto records
    assert dp.last["drifted"] and dp.last["aggregate"] \
        == pytest.approx(0.5, abs=1e-9)
    assert toy_session.drift_state() is None   # nothing attached


def test_session_tile_summary_names_every_lowered_unit(toy_session):
    tiles = toy_session.tile_summary()
    assert tiles and len(tiles) == len(toy_session.artifact.program.items)
    for t in tiles:
        assert set(t) == {"nodes", "kind", "tile"}
        assert t["kind"] in ("chain", "horizontal", "fallback")


def test_drift_attaches_to_session_serving(toy_session):
    dp = DriftProfiler.from_session(toy_session, every=2,
                                    measure_fn=_prediction_fn(toy_session),
                                    registry=MetricsRegistry())
    toy_session.attach_drift(dp)
    try:
        rng = np.random.default_rng(1)
        x = rng.integers(-128, 128, toy_session.graph.shape("data")[1:],
                         endpoint=False).astype(np.int8)
        for _ in range(4):
            toy_session.run(x)
    finally:
        toy_session.attach_drift(None)
    assert dp.n_observed == 4 and dp.n_sampled == 2
    assert not dp.report().drifted


def test_from_artifact_keeps_resolved_profile(toy_session, tmp_path):
    """Regression: loading an artifact under a profile must hand the profile
    to the constructed session (profile-guided ddr_slots auto-selection and
    session-side provenance), still without recompiling."""
    from repro import asm
    from repro.runtime import Session

    p = toy_session.profile
    path = str(tmp_path / "tuned.npz")
    asm.save_artifact(toy_session.artifact, path)
    loaded = asm.load_artifact(path)

    cache = asm.PlanCache()
    sess = Session.from_artifact(loaded, cache=cache, profile=p)
    assert sess.cache_hit and cache.misses == 0           # no recompile
    assert sess.profile == p                              # profile kept
    st = sess.stats()
    assert st["profile_hash"] == p.hash()
    assert st["session_profile_hash"] == p.hash()
    # the kept profile now drives ddr-slot auto-selection
    assert sess.pipeline_report(2, ddr_slots=None).ddr_slots_source == \
        "profile"
    # and a DriftProfiler can be built straight from the loaded session
    DriftProfiler.from_session(sess, measure_fn=lambda item: 1e-3,
                               registry=MetricsRegistry())


def test_global_tracer_disabled_by_default():
    from repro.obs import TRACER
    assert not TRACER.enabled
    assert isinstance(REGISTRY.snapshot(), dict)
