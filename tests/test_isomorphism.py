"""Algorithm 1 (subgraph isomorphism) — validity + completeness (paper C2)."""
import itertools

import pytest

pytest.importorskip("hypothesis")  # dev-only dep (requirements-dev.txt)
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core import isomorphism, templates
from repro.core.xgraph import XGraph
from tests.conftest import make_toy_resnet_graph

PAIR_TEMPLATES = [t for t in templates.KERNEL_TEMPLATES
                  if len(t.vertices) == 2]


def brute_force_pairs(g, tmpl):
    """Ground truth for 2-vertex templates: scan every edge."""
    out = set()
    for node in g:
        for c in g.consumers(node.name):
            m = {"a": node.name, "b": c}
            if (node.op in tmpl.var_types("a")
                    and g.nodes[c].op in tmpl.var_types("b")
                    and (tmpl.predicate is None or tmpl.predicate(g, m))):
                out.add((node.name, c))
    return out


def test_embeddings_match_brute_force_toy():
    g = make_toy_resnet_graph()
    for tmpl in PAIR_TEMPLATES:
        got = {(m["a"], m["b"]) for m in isomorphism.find_embeddings(g, tmpl)}
        assert got == brute_force_pairs(g, tmpl), tmpl.name


def test_embeddings_are_valid():
    g = make_toy_resnet_graph()
    for tmpl, ms in isomorphism.find_all(g, templates.ALL_TEMPLATES).items():
        for m in ms:
            # type check
            for var, node in m.items():
                assert g.nodes[node].op in tmpl.var_types(var)
            # adjacency with direction
            for (u, v) in tmpl.edges:
                assert m[u] in g.nodes[m[v]].inputs
            # injectivity
            assert len(set(m.values())) == len(m)


@st.composite
def random_dag(draw):
    """Random small CNN-ish DAGs."""
    n = draw(st.integers(3, 10))
    ops = draw(st.lists(st.sampled_from(
        ["conv", "maxpool", "eltwise_add", "upsample"]), min_size=n, max_size=n))
    g = XGraph()
    g.input("in0", (1, 32, 32, 4))
    names = ["in0"]
    for i, op in enumerate(ops):
        name = f"n{i}"
        if op == "eltwise_add" and len(names) >= 2:
            cands = [nm for nm in names if g.shape(nm) == g.shape(names[0])]
            if len(cands) >= 2:
                srcs = draw(st.permutations(cands))[:2]
                g.add(op, name, tuple(srcs))
                names.append(name)
                continue
            op = "conv"
        src = names[draw(st.integers(0, len(names) - 1))]
        if op == "conv":
            g.add("conv", name, (src,), oc=4, kernel=(3, 3), pad="same")
        elif op == "maxpool":
            g.add("maxpool", name, (src,), kernel=(2, 2), stride=(1, 1),
                  pad=(0, 0), ceil_mode=False)
        else:
            continue  # skip upsample to keep shapes aligned for eltwise
        names.append(name)
    return g


@settings(max_examples=30, deadline=None)
@given(random_dag())
def test_pairwise_completeness_random(g):
    for tmpl in PAIR_TEMPLATES:
        got = {(m["a"], m["b"]) for m in isomorphism.find_embeddings(g, tmpl)}
        assert got == brute_force_pairs(g, tmpl)


def test_start_point_is_rarest():
    """Paper's Conv+Pool example: starting from the rarer type shrinks the
    recursion tree; verify via the enumeration remaining exact when the
    pattern is asymmetric (120 convs vs 15 pools situation)."""
    g = XGraph()
    g.input("x", (1, 64, 64, 4))
    last = "x"
    for i in range(12):
        g.add("conv", f"c{i}", (last,), oc=4, kernel=(3, 3), pad="same")
        last = f"c{i}"
    g.add("maxpool", "p", (last,), kernel=(2, 2), stride=(2, 2))
    ms = isomorphism.find_embeddings(g, templates.CONV_POOL)
    assert [(m["a"], m["b"]) for m in ms] == [("c11", "p")]
