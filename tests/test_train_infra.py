"""Training loop, optimizer, grad accumulation, compression, checkpointing,
data pipeline, fault-tolerance plumbing."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.checkpoint.store import CheckpointStore
from repro.data.pipeline import SyntheticLM
from repro.distributed.health import HeartbeatMonitor, RetryPolicy, run_with_retries
from repro.launch.train import abstract_state, init_state, make_train_step
from repro.models import api
from repro.optim import compress
from repro.optim.adamw import AdamWConfig


CFG = dataclasses.replace(configs.get("smollm-360m").smoke(), n_layers=2)


def _data(cfg, batch=4, seq=64):
    return SyntheticLM(vocab=cfg.vocab, batch=batch, seq=seq)


def test_loss_decreases():
    data = _data(CFG)
    state = init_state(CFG)
    step = jax.jit(make_train_step(CFG, AdamWConfig(lr=3e-3, warmup_steps=5)))
    losses = []
    for _ in range(30):
        state, m = step(state, data.next())
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2, losses[:3] + losses[-3:]


def test_grad_accum_equivalent():
    """ga=4 over batch 8 == ga=1 same batch (fp32 accumulation)."""
    data = _data(CFG, batch=8)
    batch = data.next()
    state = init_state(CFG)
    s1 = jax.jit(make_train_step(CFG, grad_accum=1))(state, batch)
    s4 = jax.jit(make_train_step(CFG, grad_accum=4))(state, batch)
    # microbatch means != full-batch mean only through numerical association;
    # losses and updated params must agree tightly in fp32
    assert abs(float(s1[1]["loss"]) - float(s4[1]["loss"])) < 1e-4
    p1 = jax.tree.leaves(s1[0]["params"])
    p4 = jax.tree.leaves(s4[0]["params"])
    for a, b in zip(p1, p4):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-3, atol=2e-4)


def test_compression_error_feedback():
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.standard_normal((32, 32)), jnp.float32)}
    err = compress.init_error(g)
    deq1, err1 = compress.quantize_ef(g, err)
    # error feedback: g = deq + err exactly
    np.testing.assert_allclose(np.asarray(deq1["w"] + err1["w"]),
                               np.asarray(g["w"]), rtol=1e-6, atol=1e-6)
    # telescoping: deq1 + deq2 = 2g - err2, so the CUMULATIVE quantization
    # bias stays bounded by one quantization step regardless of horizon
    deq2, err2 = compress.quantize_ef(g, err1)
    np.testing.assert_allclose(np.asarray(deq1["w"] + deq2["w"]),
                               np.asarray(2 * g["w"] - err2["w"]),
                               rtol=1e-5, atol=1e-5)
    step = float(jnp.max(jnp.abs(g["w"]))) / 127.0
    assert float(jnp.max(jnp.abs(err2["w"]))) <= 1.5 * step


def test_checkpoint_roundtrip(tmp_path):
    state = init_state(CFG)
    store = CheckpointStore(str(tmp_path))
    store.save(state, step=7)
    restored, step = store.restore_latest(jax.eval_shape(lambda: state))
    assert step == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_async_and_gc(tmp_path):
    state = init_state(CFG)
    store = CheckpointStore(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        store.save(state, step=s, async_write=True)
        store.wait()
    assert store.steps() == [3, 4]


def test_checkpoint_crash_safety(tmp_path):
    """A checkpoint without COMMITTED is invisible."""
    state = init_state(CFG)
    store = CheckpointStore(str(tmp_path))
    p = store.save(state, step=1)
    os.remove(os.path.join(p, "COMMITTED"))
    assert store.steps() == []
    assert store.restore_latest(jax.eval_shape(lambda: state)) is None


def test_retry_driver_resumes_from_checkpoint(tmp_path):
    store = CheckpointStore(str(tmp_path))
    state = init_state(CFG)
    abstract = jax.eval_shape(lambda: state)
    calls = {"n": 0}

    def run(st, start):
        calls["n"] += 1
        if calls["n"] == 1:
            store.save(st, step=13)
            raise RuntimeError("simulated host failure")
        return st, start

    policy = RetryPolicy(max_restarts=3)
    _, start = run_with_retries(lambda: state, run, store, policy, abstract)
    assert start == 13 and calls["n"] == 2


def test_retry_budget_exhausts():
    policy = RetryPolicy(max_restarts=1, window_s=1000)
    assert policy.should_retry()
    policy.record()
    assert not policy.should_retry()


def test_heartbeat_and_stragglers():
    t = [0.0]
    mon = HeartbeatMonitor(timeout_s=10, clock=lambda: t[0])
    for h in ("h0", "h1", "h2", "h3"):
        mon.beat(h, step_time_s=1.0)
    mon.beat("h3", step_time_s=1.0)
    for _ in range(8):
        mon.beat("h2", step_time_s=5.0)      # slow host
    assert mon.stragglers() == ["h2"]
    t[0] = 20.0
    mon.beat("h0")
    assert set(mon.dead()) == {"h1", "h2", "h3"}


def test_data_determinism_and_seek():
    d1 = SyntheticLM(vocab=100, batch=4, seq=16)
    d2 = SyntheticLM(vocab=100, batch=4, seq=16)
    a = [d1.next() for _ in range(3)]
    d2.seek(2)
    b = d2.next()
    np.testing.assert_array_equal(np.asarray(a[2]["tokens"]),
                                  np.asarray(b["tokens"]))


def test_data_host_sharding_disjoint():
    full = SyntheticLM(vocab=100, batch=8, seq=16)
    h0 = SyntheticLM(vocab=100, batch=8, seq=16, host_index=0, host_count=2)
    h1 = SyntheticLM(vocab=100, batch=8, seq=16, host_index=1, host_count=2)
    b0, b1 = h0.next(), h1.next()
    assert b0["tokens"].shape == (4, 16)
    assert not np.array_equal(np.asarray(b0["tokens"]),
                              np.asarray(b1["tokens"]))


def test_moment_dtype_bf16():
    state = init_state(CFG, AdamWConfig(moment_dtype="bfloat16"))
    assert jax.tree.leaves(state["opt"]["m"])[0].dtype == jnp.bfloat16
