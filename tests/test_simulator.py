"""Time-wheel simulator semantics (paper C5/C6)."""
from repro.core import isa, simulator
from repro.core.cost import AnalyticEvaluator, SimulatorEvaluator
from repro.core.isa import Instr
from repro.hw import ZU2
from tests.conftest import make_toy_resnet_graph


def test_dependencies_and_engine_order():
    instrs = [
        Instr(0, "DDR_RD", "LOAD", 10),
        Instr(1, "CONV", "CONV", 100, (0,)),
        Instr(2, "DDR_RD", "LOAD", 10),          # overlaps CONV (double buffer)
        Instr(3, "CONV", "CONV", 100, (2,)),
        Instr(4, "DDR_WR", "SAVE", 10, (3,)),
    ]
    rep = simulator.run(instrs)
    # loads: 0-10, 10-20; convs: 10-110, 110-210; save: 210-220
    assert rep.total_cycles == 220
    assert rep.busy_cycles["CONV"] == 200


def test_load_overlaps_save_full_duplex():
    instrs = [
        Instr(0, "DDR_RD", "LOAD", 50),
        Instr(1, "DDR_WR", "SAVE", 50),          # independent: overlaps
    ]
    assert simulator.run(instrs).total_cycles == 50


def test_fused_no_slower_than_unfused():
    g = make_toy_resnet_graph()
    sim = SimulatorEvaluator(g, ZU2)
    assert sim(["c3", "p1"]) <= sim(["c3"]) + sim(["p1"]) + 1e-12
    assert sim(["c2b", "add1"]) <= sim(["c2b"]) + sim(["add1"]) + 1e-12


def test_strategy_report_engine_utilization():
    g = make_toy_resnet_graph()
    from repro.core import pathsearch

    s = pathsearch.search(g, ZU2)
    sim = SimulatorEvaluator(g, ZU2)
    rep = sim.strategy_report(s)
    assert rep.total_cycles > 0
    assert 0.0 < rep.utilization("CONV") <= 1.0
    # total >= the busiest engine's occupancy
    assert rep.total_cycles >= max(rep.busy_cycles.values())


def test_dataflow_deps_let_branches_overlap():
    """Independent Inception-style branches overlap their engines."""
    g = make_toy_resnet_graph()
    ana = AnalyticEvaluator(g, ZU2)
    groups = [["c1"], ["c2a"], ["c2s"], ["c2b"], ["add1"], ["c3"], ["p1"], ["fc1"]]
    tilings = [ana.cost(grp).tiling for grp in groups]
    instrs = isa.emit_strategy(g, groups, tilings, ZU2)
    rep = simulator.run(instrs)
    serial = sum(ana(grp) for grp in groups)
    assert rep.seconds(ZU2.freq_hz) <= serial * 1.05
