"""conv_fused Pallas kernel: bit-exact vs the int8 oracle across a
shape/stride/pool/eltwise sweep (interpret mode)."""
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # dev-only dep (requirements-dev.txt)
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.kernels.conv_fused.ops import fused_conv_block, supports
from repro.kernels.conv_fused.ref import fused_conv_ref


def _data(rng, h, w, ic, oc, k):
    x = rng.integers(-128, 128, (1, h, w, ic)).astype(np.int8)
    wt = rng.integers(-128, 128, (k, k, ic, oc)).astype(np.int8)
    b = rng.integers(-2000, 2000, oc).astype(np.int32)
    return jnp.asarray(x), jnp.asarray(wt), jnp.asarray(b)


CASES = [
    # (h, w, ic, oc, k, stride, pad, relu, shift)
    (8, 8, 4, 8, 3, 1, 1, True, 6),
    (8, 8, 4, 8, 3, 1, 1, False, 6),
    (9, 9, 3, 5, 3, 1, 0, True, 7),       # ragged dims
    (12, 12, 8, 16, 5, 1, 2, True, 8),
    (12, 12, 8, 16, 3, 2, 1, True, 7),    # stride 2
    (16, 16, 16, 4, 1, 1, 0, True, 5),    # 1x1
    (7, 7, 2, 3, 3, 2, 1, False, 4),      # everything ragged
]


@pytest.mark.parametrize("h,w,ic,oc,k,s,p,relu,shift", CASES)
def test_plain_conv_bit_exact(h, w, ic, oc, k, s, p, relu, shift):
    rng = np.random.default_rng(h * w + oc)
    x, wt, b = _data(rng, h, w, ic, oc, k)
    got = fused_conv_block(x, wt, b, stride=(s, s), pad=(p, p), shift=shift,
                           relu=relu)
    want = fused_conv_ref(x, wt, b, stride=(s, s), pad=(p, p), shift=shift,
                          relu=relu)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


POOL_CASES = [
    # (h, w, ic, oc, k, pad, kp, sp)
    (8, 8, 4, 8, 3, 1, 2, 2),
    (10, 10, 4, 8, 3, 1, 2, 2),
    (8, 8, 4, 8, 3, 1, 3, 1),
    (12, 12, 3, 6, 5, 2, 2, 2),
    (14, 14, 8, 16, 3, 1, 3, 1),
]


@pytest.mark.parametrize("h,w,ic,oc,k,p,kp,sp", POOL_CASES)
def test_conv_pool_bit_exact(h, w, ic, oc, k, p, kp, sp):
    rng = np.random.default_rng(h + kp * 10)
    x, wt, b = _data(rng, h, w, ic, oc, k)
    oh = h + 2 * p - k + 1
    assert supports(kernel=(k, k), stride=(1, 1), pool=(kp, sp),
                    conv_oh=oh, conv_ow=oh)
    got = fused_conv_block(x, wt, b, stride=(1, 1), pad=(p, p), shift=7,
                           relu=True, pool=(kp, sp))
    want = fused_conv_ref(x, wt, b, stride=(1, 1), pad=(p, p), shift=7,
                          relu=True, pool=(kp, sp))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("relu_out", [False, True])
def test_conv_eltwise_bit_exact(relu_out):
    rng = np.random.default_rng(3)
    x, wt, b = _data(rng, 8, 8, 4, 8, 3)
    side = jnp.asarray(rng.integers(-128, 128, (1, 8, 8, 8)).astype(np.int8))
    elt = (side, 1, 2, relu_out)
    got = fused_conv_block(x, wt, b, stride=(1, 1), pad=(1, 1), shift=6,
                           relu=False, eltwise=elt)
    want = fused_conv_ref(x, wt, b, stride=(1, 1), pad=(1, 1), shift=6,
                          relu=False, eltwise=elt)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=15, deadline=None)
@given(st.integers(4, 12), st.integers(4, 12), st.sampled_from([1, 2, 3, 4]),
       st.sampled_from([1, 2, 4, 8]), st.sampled_from([1, 3]),
       st.integers(0, 10), st.booleans())
def test_property_sweep(h, w, ic, oc, k, shift, relu):
    rng = np.random.default_rng(h * 31 + w)
    x, wt, b = _data(rng, h, w, ic, oc, k)
    p = (k - 1) // 2
    got = fused_conv_block(x, wt, b, stride=(1, 1), pad=(p, p), shift=shift,
                           relu=relu)
    want = fused_conv_ref(x, wt, b, stride=(1, 1), pad=(p, p), shift=shift,
                          relu=relu)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_unsupported_patterns_fall_back():
    assert not supports(kernel=(3, 3), stride=(1, 1), dilation=(2, 2))
    assert not supports(kernel=(3, 3), stride=(1, 2))
    assert not supports(kernel=(3, 3), stride=(1, 1), depthwise=True)
    # pool windows not tiling the conv output exactly
    assert not supports(kernel=(3, 3), stride=(1, 1), pool=(3, 2),
                        conv_oh=8, conv_ow=8)
