"""conv_fused Pallas kernel: bit-exact vs the int8 oracle across a
shape/stride/pool/eltwise sweep (interpret mode)."""
import jax.numpy as jnp
import numpy as np
import pytest

try:  # dev-only dep (requirements-dev.txt); only the property sweep needs it
    import hypothesis.strategies as st
    from hypothesis import given, settings
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.kernels.conv_fused.ops import fused_conv_block, supports
from repro.kernels.conv_fused.ref import fused_conv_ref


def _data(rng, h, w, ic, oc, k):
    x = rng.integers(-128, 128, (1, h, w, ic)).astype(np.int8)
    wt = rng.integers(-128, 128, (k, k, ic, oc)).astype(np.int8)
    b = rng.integers(-2000, 2000, oc).astype(np.int32)
    return jnp.asarray(x), jnp.asarray(wt), jnp.asarray(b)


CASES = [
    # (h, w, ic, oc, k, stride, pad, relu, shift)
    (8, 8, 4, 8, 3, 1, 1, True, 6),
    (8, 8, 4, 8, 3, 1, 1, False, 6),
    (9, 9, 3, 5, 3, 1, 0, True, 7),       # ragged dims
    (12, 12, 8, 16, 5, 1, 2, True, 8),
    (12, 12, 8, 16, 3, 2, 1, True, 7),    # stride 2
    (16, 16, 16, 4, 1, 1, 0, True, 5),    # 1x1
    (7, 7, 2, 3, 3, 2, 1, False, 4),      # everything ragged
]


@pytest.mark.parametrize("h,w,ic,oc,k,s,p,relu,shift", CASES)
def test_plain_conv_bit_exact(h, w, ic, oc, k, s, p, relu, shift):
    rng = np.random.default_rng(h * w + oc)
    x, wt, b = _data(rng, h, w, ic, oc, k)
    got = fused_conv_block(x, wt, b, stride=(s, s), pad=(p, p), shift=shift,
                           relu=relu)
    want = fused_conv_ref(x, wt, b, stride=(s, s), pad=(p, p), shift=shift,
                          relu=relu)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


POOL_CASES = [
    # (h, w, ic, oc, k, pad, kp, sp)
    (8, 8, 4, 8, 3, 1, 2, 2),
    (10, 10, 4, 8, 3, 1, 2, 2),
    (8, 8, 4, 8, 3, 1, 3, 1),
    (12, 12, 3, 6, 5, 2, 2, 2),
    (14, 14, 8, 16, 3, 1, 3, 1),
]


@pytest.mark.parametrize("h,w,ic,oc,k,p,kp,sp", POOL_CASES)
def test_conv_pool_bit_exact(h, w, ic, oc, k, p, kp, sp):
    rng = np.random.default_rng(h + kp * 10)
    x, wt, b = _data(rng, h, w, ic, oc, k)
    oh = h + 2 * p - k + 1
    assert supports(kernel=(k, k), stride=(1, 1), pool=(kp, sp),
                    conv_oh=oh, conv_ow=oh)
    got = fused_conv_block(x, wt, b, stride=(1, 1), pad=(p, p), shift=7,
                           relu=True, pool=(kp, sp))
    want = fused_conv_ref(x, wt, b, stride=(1, 1), pad=(p, p), shift=7,
                          relu=True, pool=(kp, sp))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("relu_out", [False, True])
def test_conv_eltwise_bit_exact(relu_out):
    rng = np.random.default_rng(3)
    x, wt, b = _data(rng, 8, 8, 4, 8, 3)
    side = jnp.asarray(rng.integers(-128, 128, (1, 8, 8, 8)).astype(np.int8))
    elt = (side, 1, 2, relu_out)
    got = fused_conv_block(x, wt, b, stride=(1, 1), pad=(1, 1), shift=6,
                           relu=False, eltwise=elt)
    want = fused_conv_ref(x, wt, b, stride=(1, 1), pad=(1, 1), shift=6,
                          relu=False, eltwise=elt)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


if HAVE_HYPOTHESIS:
    @settings(max_examples=15, deadline=None)
    @given(st.integers(4, 12), st.integers(4, 12),
           st.sampled_from([1, 2, 3, 4]), st.sampled_from([1, 2, 4, 8]),
           st.sampled_from([1, 3]), st.integers(0, 10), st.booleans())
    def test_property_sweep(h, w, ic, oc, k, shift, relu):
        rng = np.random.default_rng(h * 31 + w)
        x, wt, b = _data(rng, h, w, ic, oc, k)
        p = (k - 1) // 2
        got = fused_conv_block(x, wt, b, stride=(1, 1), pad=(p, p),
                               shift=shift, relu=relu)
        want = fused_conv_ref(x, wt, b, stride=(1, 1), pad=(p, p),
                              shift=shift, relu=relu)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_support_predicate():
    # depthwise is the chain kernel's only structural exclusion
    assert not supports(kernel=(3, 3), stride=(1, 1), depthwise=True)
    # the staged kernel's padded-coordinate masking handles all of these
    assert supports(kernel=(3, 3), stride=(1, 1), dilation=(2, 2))
    assert supports(kernel=(3, 3), stride=(1, 2))
    assert supports(kernel=(3, 3), stride=(1, 1), pool=(3, 2),
                    conv_oh=8, conv_ow=8)   # ceil-extended pool windows


def test_dilated_conv_bit_exact():
    from repro.core import int8_ops
    from repro.kernels.conv_fused.ops import _run_chain

    rng = np.random.default_rng(11)
    x, wt, b = _data(rng, 12, 12, 4, 8, 3)
    want = int8_ops.conv2d(x, wt, b, stride=(1, 1), pad=(2, 2),
                           dilation=(2, 2), shift=6, relu=True)
    chain = (("conv", "c", 3, 3, 1, 1, 2, 2, 2, 2, 6, True, 12, 12),)
    got = _run_chain(x, (wt,), (b,), (), chain=chain, oh=12, ow=12, oc=8,
                     interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_ceil_pool_chain_bit_exact():
    """conv -> maxpool with pool padding AND a ceil-extended last window —
    the pre-padded-slack path the lowering pass emits for ResNet's pool1."""
    import math

    from repro.core import int8_ops
    from repro.kernels.conv_fused.ops import _run_chain

    rng = np.random.default_rng(12)
    x, wt, b = _data(rng, 13, 13, 4, 8, 3)
    y_c = fused_conv_ref(x, wt, b, stride=(1, 1), pad=(1, 1), shift=6,
                         relu=True)
    for kp, sp, pp in [(3, 2, 0), (3, 2, 1), (2, 2, 1)]:
        want = int8_ops.maxpool(y_c, kernel=(kp, kp), stride=(sp, sp),
                                pad=(pp, pp), ceil_mode=True)
        oh = math.ceil((13 + 2 * pp - kp) / sp) + 1
        chain = (("conv", "c", 3, 3, 1, 1, 1, 1, 1, 1, 6, True, 13, 13),
                 ("pool", "p", "max", kp, kp, sp, sp, pp, pp, oh, oh, kp * kp))
        got = _run_chain(x, (wt,), (b,), (), chain=chain, oh=oh, ow=oh,
                         oc=8, interpret=True)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_avgpool_chain_bit_exact():
    from repro.core import int8_ops
    from repro.kernels.conv_fused.ops import _run_chain

    rng = np.random.default_rng(13)
    x, wt, b = _data(rng, 12, 12, 4, 8, 3)
    y_c = fused_conv_ref(x, wt, b, stride=(1, 1), pad=(1, 1), shift=6,
                         relu=True)
    want = int8_ops.avgpool(y_c, kernel=(2, 2), stride=(2, 2))
    chain = (("conv", "c", 3, 3, 1, 1, 1, 1, 1, 1, 6, True, 12, 12),
             ("pool", "p", "avg", 2, 2, 2, 2, 0, 0, 6, 6, 4))
    got = _run_chain(x, (wt,), (b,), (), chain=chain, oh=6, ow=6, oc=8,
                     interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_horizontal_stacked_bit_exact():
    """Two siblings with different shifts/ReLU in one stacked launch must
    match each sibling computed alone (per-channel requantization)."""
    import jax.numpy as jnp

    from repro.core import int8_ops
    from repro.kernels.conv_fused.ops import _run_horizontal

    rng = np.random.default_rng(14)
    x = jnp.asarray(rng.integers(-128, 128, (1, 10, 10, 4)).astype(np.int8))
    wa = jnp.asarray(rng.integers(-128, 128, (3, 3, 4, 8)).astype(np.int8))
    wb = jnp.asarray(rng.integers(-128, 128, (3, 3, 4, 12)).astype(np.int8))
    ba = jnp.asarray(rng.integers(-2000, 2000, 8).astype(np.int32))
    bb = jnp.asarray(rng.integers(-2000, 2000, 12).astype(np.int32))
    ya = int8_ops.conv2d(x, wa, ba, stride=(1, 1), pad=(1, 1), shift=5,
                         relu=True)
    yb = int8_ops.conv2d(x, wb, bb, stride=(1, 1), pad=(1, 1), shift=7)
    y = _run_horizontal(
        x, jnp.concatenate([wa, wb], axis=-1), jnp.concatenate([ba, bb]),
        jnp.asarray(np.repeat([5, 7], [8, 12]).astype(np.int32)),
        jnp.asarray(np.repeat([1, 0], [8, 12]).astype(np.int32)),
        stride=(1, 1), pad=(1, 1), oh=10, ow=10, interpret=True)
    np.testing.assert_array_equal(np.asarray(y[..., :8]), np.asarray(ya))
    np.testing.assert_array_equal(np.asarray(y[..., 8:]), np.asarray(yb))
