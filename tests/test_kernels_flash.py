"""Flash-attention Pallas kernel vs the unfused softmax oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref


def _qkv(rng, b, sq, sk, h, kv, d, dtype):
    q = rng.standard_normal((b, sq, h, d)).astype(dtype)
    k = rng.standard_normal((b, sk, kv, d)).astype(dtype)
    v = rng.standard_normal((b, sk, kv, d)).astype(dtype)
    return jnp.asarray(q), jnp.asarray(k), jnp.asarray(v)


CASES = [
    # (b, sq, sk, h, kv, d, blk, offset)
    (1, 64, 64, 2, 2, 16, 16, 0),       # MHA
    (1, 64, 64, 4, 2, 16, 16, 0),       # GQA 2:1
    (2, 32, 32, 6, 2, 8, 8, 0),         # GQA 3:1
    (1, 16, 64, 2, 1, 16, 16, 48),      # q_offset (chunked prefill tail)
    (1, 128, 128, 2, 2, 32, 32, 0),     # more blocks
]


@pytest.mark.parametrize("b,sq,sk,h,kv,d,blk,off", CASES)
def test_flash_matches_ref_fp32(b, sq, sk, h, kv, d, blk, off):
    rng = np.random.default_rng(sq + h)
    q, k, v = _qkv(rng, b, sq, sk, h, kv, d, np.float32)
    got = flash_attention(q, k, v, q_offset=off, blk_q=blk, blk_k=blk)
    want = attention_ref(q, k, v, q_offset=off)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_bf16():
    rng = np.random.default_rng(7)
    q, k, v = _qkv(rng, 1, 64, 64, 2, 2, 16, np.float32)
    q, k, v = (x.astype(jnp.bfloat16) for x in (q, k, v))
    got = flash_attention(q, k, v, blk_q=16, blk_k=16).astype(jnp.float32)
    want = attention_ref(q, k, v).astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=0.05, atol=0.05)


def test_flash_noncausal():
    rng = np.random.default_rng(9)
    q, k, v = _qkv(rng, 1, 32, 64, 2, 2, 16, np.float32)
    got = flash_attention(q, k, v, causal=False, blk_q=16, blk_k=16)
    want = attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_chunked_xla_matches_ref():
    """sdpa_chunked (the dry-run-lowerable flash twin) == plain softmax."""
    from repro.nn.attention import sdpa, sdpa_chunked

    rng = np.random.default_rng(1)
    q, k, v = _qkv(rng, 2, 256, 256, 6, 2, 16, np.float32)
    for causal in (True, False):
        got = sdpa_chunked(q, k, v, causal=causal, blk=64)
        want = sdpa(q, k, v, causal=causal, impl="xla")
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=3e-5, atol=3e-5)


def test_chunked_fallbacks():
    """Non-divisible block / SWA fall back to the reference path."""
    from repro.nn.attention import sdpa, sdpa_chunked

    rng = np.random.default_rng(2)
    q, k, v = _qkv(rng, 1, 48, 48, 2, 2, 8, np.float32)
    got = sdpa_chunked(q, k, v, causal=True, blk=64)   # 48 % 64 != 0
    want = sdpa(q, k, v, causal=True, impl="xla")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


def test_flash_in_model_path():
    """cfg.attn_impl='flash' integrates through the model forward."""
    from repro import configs
    from repro.models import api
    import dataclasses

    cfg = dataclasses.replace(configs.get("granite-8b").smoke(),
                              attn_impl="flash")
    params = api.init_params(cfg)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (1, 64)), jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab, (1, 64)), jnp.int32)}
    loss_flash = float(api.loss_fn(cfg, params, batch))
    cfg_x = dataclasses.replace(cfg, attn_impl="xla")
    loss_xla = float(api.loss_fn(cfg_x, params, batch))
    assert abs(loss_flash - loss_xla) < 1e-3
