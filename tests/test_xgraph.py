"""XGraph IR + front-end lowering (paper C1)."""
import pytest

from repro.core import frontend
from repro.core.xgraph import XGraph
from tests.conftest import make_toy_resnet_graph


def test_shape_inference_conv_pool():
    g = XGraph()
    g.input("x", (1, 224, 224, 3))
    g.add("conv", "c", ("x",), oc=64, kernel=(7, 7), stride=(2, 2), pad="same")
    assert g.shape("c") == (1, 112, 112, 64)
    g.add("maxpool", "p", ("c",), kernel=(3, 3), stride=(2, 2), pad=(0, 0))
    assert g.shape("p") == (1, 56, 56, 64)  # caffe ceil mode, pad 0
    g.add("global_avgpool", "gap", ("p",))
    assert g.shape("gap") == (1, 1, 1, 64)


def test_macs_eq3():
    """Paper Eq. 3: A_comp = 2 k_w k_h IC OC H W — Fig. 8's example is
    0.32 GOPs."""
    g = XGraph()
    g.input("x", (1, 28, 28, 32))
    g.add("conv", "c", ("x",), oc=256, kernel=(5, 5), stride=(1, 1), pad="same")
    assert g.ops("c") == 2 * 5 * 5 * 32 * 256 * 28 * 28


def test_frontend_pointwise_and_flatten():
    g = make_toy_resnet_graph()
    ops = {n.op for n in g}
    assert "relu" not in ops, "relu must be fused to the nonlinear bit"
    assert "flatten" not in ops, "NHWC flatten must be pruned"
    assert g.nodes["c1"].attrs.get("relu") == "relu"
    assert g.nodes["add1"].attrs.get("relu") == "relu"


def test_frontend_tf_style_equivalence():
    """Fine-grained TF-style chain collapses to one coarse conv (Fig. 4)."""
    g = XGraph()
    g.input("x", (1, 8, 8, 4))
    frontend.tf_style_conv(g, "conv", "x", oc=8, kernel=3, relu=True)
    frontend.lower(g)
    assert [n.op for n in g] == ["input", "conv"]
    node = g.nodes["conv"]
    assert node.attrs.get("relu") and node.attrs["pad"] == (1, 1)
    assert [("bias_add", {})] == [(o, {}) for o, _ in
                                  node.attrs["folded_intrinsics"]][:1]


def test_bn_fold_recorded():
    g = XGraph()
    g.input("x", (1, 8, 8, 4))
    g.add("conv", "c", ("x",), oc=8, kernel=(3, 3), pad="same")
    g.add("bn", "b", ("c",), gamma=2.0, beta=0.5, mean=0.1, var=1.0)
    g.add("scale", "s", ("b",), alpha=3.0)
    frontend.lower(g)
    folded = g.nodes["c"].attrs["folded_intrinsics"]
    assert [f[0] for f in folded] == ["bn", "scale"]


def test_concat_folded_zero_cost():
    g = XGraph()
    g.input("x", (1, 8, 8, 4))
    g.add("conv", "a", ("x",), oc=4, kernel=(1, 1), pad="same")
    g.add("conv", "b", ("x",), oc=4, kernel=(1, 1), pad="same")
    g.add("concat", "cat", ("a", "b"))
    frontend.lower(g)
    assert g.nodes["cat"].attrs.get("folded") is True
    assert g.shape("cat") == (1, 8, 8, 8)


def test_duplicate_and_unknown_nodes_rejected():
    g = XGraph()
    g.input("x", (1, 4, 4, 2))
    with pytest.raises(ValueError):
        g.input("x", (1, 4, 4, 2))
    with pytest.raises(ValueError):
        g.add("conv", "c", ("nope",), oc=2, kernel=(1, 1))
