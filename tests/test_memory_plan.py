"""Memory planner + addressed assembler + hazard oracle (ISSUE 1 tentpole).

Covers the assembler/simulator seam: cross-group SAVE->LOAD dependency bits,
per-engine utilization bounds, liveness/first-fit invariants, ping/pong bank
planning, the memory-hazard checker (including a deliberately broken DDR
plan), and the artifact round trip for the paper's models.
"""
import numpy as np
import pytest

from repro import asm
from repro.core import executor, pathsearch, quantize, simulator, validate
from repro.core.cost import AnalyticEvaluator, SimulatorEvaluator
from repro.core.isa import Instr, emit_strategy
from repro.core.tiling import GroupTiling
from repro.cnn import build, init_params
from repro.hw import ZU2
from repro.memory import (activation_intervals, first_fit, plan_banks,
                          plan_memory, MemoryPlanError)
from tests.conftest import make_toy_resnet_graph, toy_params


def _planned(g, dev=ZU2, strat_fn=pathsearch.search):
    s = strat_fn(g, dev)
    items = pathsearch.order_groups(g, [list(x) for x in s.groups] +
                                    [list(h) for h in s.horizontal])
    hset = {tuple(h) for h in s.horizontal}
    ana = AnalyticEvaluator(g, dev)
    from repro.core import tiling as tiling_mod
    tilings = [tiling_mod.solve_horizontal(g, grp, dev) if tuple(grp) in hset
               else ana.cost(grp).tiling for grp in items]
    plan = plan_memory(g, items, tilings, dev)
    instrs = emit_strategy(g, items, tilings, dev, plan=plan)
    return s, items, tilings, plan, instrs


# ----------------------------------------------------------------- liveness
def test_liveness_intervals_cover_schedule():
    g = make_toy_resnet_graph()
    s, items, tilings, plan, _ = _planned(g)
    ivs = plan.intervals
    by_gid = {iv.writer_gid: iv for iv in ivs}
    # one buffer per group plus the graph input
    assert len(ivs) == len(items) + 1
    assert by_gid[-1].name == "in:data" and by_gid[-1].start == -1
    for iv in ivs:
        assert iv.end >= iv.start
        assert iv.nbytes > 0
    # the input is read by the first consuming group, not live forever
    assert by_gid[-1].end < len(items)


def test_liveness_host_consumed_buffer_lives_to_end():
    g = make_toy_resnet_graph()
    from repro.core import partition
    dv = partition.device_of(g, "paper")   # fc1 on the host
    s = pathsearch.search(g, ZU2, device_of=dv)
    items = pathsearch.order_groups(g, [list(x) for x in s.groups] +
                                    [list(h) for h in s.horizontal])
    ivs = activation_intervals(g, items)
    # p1 feeds the host-side fc1 -> its buffer must live to the end
    owner = {iv.writer_gid: iv for iv in ivs}
    (p1_iv,) = [iv for iv in ivs if "p1" in iv.parts]
    assert p1_iv.end == len(items)


# ------------------------------------------------------------------ ddr_alloc
def test_first_fit_disjoint_when_live_and_reuses_when_dead():
    from repro.memory.liveness import Interval
    ivs = [Interval("a", 100, 0, 2, 0),
           Interval("b", 100, 1, 3, 1),    # overlaps a -> disjoint addresses
           Interval("c", 100, 4, 5, 2)]    # a, b dead -> reuses offset 0
    plan = first_fit(ivs, align=64)
    a, b, c = (plan.placements[k] for k in "abc")
    assert a.offset + a.size <= b.offset or b.offset + b.size <= a.offset
    assert c.offset == 0
    assert "c" in plan.reuses and "a" in plan.reuses["c"]
    assert plan.peak_bytes < plan.no_reuse_bytes
    assert plan.reuse_factor > 1.0


def test_first_fit_alignment():
    from repro.memory.liveness import Interval
    plan = first_fit([Interval("a", 10, 0, 1, 0), Interval("b", 10, 0, 1, 1)],
                     align=64)
    for p in plan.placements.values():
        assert p.offset % 64 == 0 and p.size % 64 == 0


# ---------------------------------------------------------------------- banks
def test_bank_plan_ping_pong_and_fallback():
    dev = ZU2
    small = GroupTiling(True, n_spatial_tiles=4,
                        in_tile_bytes=dev.buf_in_bytes // 4,
                        out_tile_bytes=dev.buf_out_bytes // 4)
    bp = plan_banks(small, dev)
    assert bp.feasible and bp.n_banks_in == 2 and bp.n_banks_out == 2
    assert bp.in_bank_bytes == dev.buf_in_bytes // 2

    big = GroupTiling(True, n_spatial_tiles=4,
                      in_tile_bytes=int(dev.buf_in_bytes * 0.8),
                      out_tile_bytes=int(dev.buf_out_bytes * 0.8))
    bp = plan_banks(big, dev)
    assert bp.feasible and bp.n_banks_in == 1 and bp.n_banks_out == 1


def test_bank_plan_rejects_oversized_tile():
    dev = ZU2
    t = GroupTiling(True, n_spatial_tiles=1,
                    in_tile_bytes=dev.buf_in_bytes + 1)
    bp = plan_banks(t, dev)
    assert not bp.feasible and "exceeds B_in" in bp.reason

    t = GroupTiling(True, n_spatial_tiles=1, out_tile_bytes=1,
                    resident_bytes=dev.buf_out_bytes)
    assert not plan_banks(t, dev).feasible


def test_plan_memory_raises_on_infeasible_bank():
    g = make_toy_resnet_graph()
    s, items, tilings, _, _ = _planned(g)
    bad = list(tilings)
    bad[0] = GroupTiling(True, n_spatial_tiles=1,
                         in_tile_bytes=ZU2.buf_in_bytes + 1)
    with pytest.raises(MemoryPlanError):
        plan_memory(g, items, bad, ZU2)


# --------------------------------------------------- assembler/simulator seam
def test_emit_strategy_cross_group_save_load_deps():
    """A consumer group's first LOAD carries the producer group's SAVE id."""
    g = make_toy_resnet_graph()
    s, items, tilings, plan, instrs = _planned(g)
    by_iid = {i.iid: i for i in instrs}
    checked = 0
    for gi, grp in enumerate(items):
        gset = set(grp)
        ext = {i for nm in grp for i in g.nodes[nm].inputs if i not in gset}
        producers = {pgi for pgi, pgrp in enumerate(items)
                     if pgi != gi and ext & set(pgrp)}
        first_load = next(i for i in instrs
                          if i.group_id == gi and i.opcode == "LOAD")
        dep_groups = {by_iid[d].group_id for d in first_load.deps
                      if by_iid[d].opcode == "SAVE" and by_iid[d].group_id != gi}
        for pgi in producers:
            assert pgi in dep_groups, (
                f"group {gi} {grp} must wait on producer group {pgi}")
            checked += 1
    assert checked > 0


def test_every_load_save_addressed_and_banked():
    g = make_toy_resnet_graph()
    _, _, _, plan, instrs = _planned(g)
    for i in instrs:
        if i.opcode in ("LOAD", "SAVE"):
            assert i.bank >= 0 and i.group_id >= 0 and i.tile >= 0
        if i.opcode == "SAVE":
            assert i.ddr_addr >= 0 and i.ddr_len > 0


def test_simulator_utilization_bounded():
    g = make_toy_resnet_graph()
    _, _, _, _, instrs = _planned(g)
    rep = simulator.run(instrs)
    assert rep.total_cycles > 0
    for eng in ("DDR_RD", "DDR_WR", "CONV", "POOL", "MISC"):
        assert 0.0 <= rep.utilization(eng) <= 1.0
    assert rep.total_cycles >= max(rep.busy_cycles.values())


def test_planned_stream_passes_hazard_check():
    g = make_toy_resnet_graph()
    _, _, _, _, instrs = _planned(g)
    rep = simulator.check(instrs)   # raises on any hazard
    assert rep.n_instructions == len(instrs)


def test_addressing_does_not_slow_down_schedule_unboundedly():
    """Bank/WAR dependency bits serialize only what hardware must serialize;
    the addressed schedule stays within 2x of the timing-only one."""
    g = make_toy_resnet_graph()
    s, items, tilings, plan, instrs = _planned(g)
    plain = emit_strategy(g, items, tilings, ZU2)   # no plan
    t_plain = simulator.run(plain).total_cycles
    t_addr = simulator.run(instrs).total_cycles
    assert t_addr >= t_plain          # extra constraints can only delay
    assert t_addr <= 2 * t_plain


# ------------------------------------------------------------- hazard oracle
def test_hazard_checker_catches_overlapping_ddr_writes():
    """Two groups write overlapping DDR while the first is still being read."""
    instrs = [
        Instr(0, "DDR_WR", "SAVE", 100, (), ddr_addr=0, ddr_len=512,
              group_id=0, tile=0),
        # group 1 reads group 0's buffer...
        Instr(1, "DDR_RD", "LOAD", 200, (0,), ddr_addr=0, ddr_len=512,
              group_id=1, tile=0),
        # ...while group 2 (no dependency!) clobbers the same addresses
        Instr(2, "DDR_WR", "SAVE", 100, (), ddr_addr=256, ddr_len=512,
              group_id=2, tile=0),
    ]
    rep, times = simulator.run_times(instrs)
    hazards = simulator.memory_hazards(instrs, times)
    assert hazards and "DDR overlap" in hazards[0]
    with pytest.raises(simulator.MemoryHazardError):
        simulator.check(instrs)


def test_hazard_checker_accepts_war_protected_reuse():
    """Same plan, but with the write-after-read bit the assembler emits."""
    instrs = [
        Instr(0, "DDR_WR", "SAVE", 100, (), ddr_addr=0, ddr_len=512,
              group_id=0, tile=0),
        Instr(1, "DDR_RD", "LOAD", 200, (0,), ddr_addr=0, ddr_len=512,
              group_id=1, tile=0),
        Instr(2, "DDR_WR", "SAVE", 100, (1,), ddr_addr=256, ddr_len=512,
              group_id=2, tile=0),
    ]
    rep, times = simulator.run_times(instrs)
    assert simulator.memory_hazards(instrs, times) == []


def test_hazard_checker_catches_ping_pong_bank_overwrite():
    """LOAD(t+2) streams into bank 0 while CONV(t) still reads it."""
    instrs = [
        Instr(0, "DDR_RD", "LOAD", 10, (), bank=0, group_id=0, tile=0),
        Instr(1, "CONV", "CONV", 1000, (0,), group_id=0, tile=0),
        Instr(2, "DDR_RD", "LOAD", 10, (), bank=1, group_id=0, tile=1),
        Instr(3, "CONV", "CONV", 1000, (2,), group_id=0, tile=1),
        Instr(4, "DDR_RD", "LOAD", 10, (), bank=0, group_id=0, tile=2),  # !!
        Instr(5, "CONV", "CONV", 1000, (4,), group_id=0, tile=2),
    ]
    rep, times = simulator.run_times(instrs)
    hazards = simulator.memory_hazards(instrs, times)
    assert hazards and "in-bank hazard" in hazards[0]
    # with the bank-reuse dependency bit the assembler emits, it is clean
    instrs[4].deps = (1,)
    rep, times = simulator.run_times(instrs)
    assert simulator.memory_hazards(instrs, times) == []


# --------------------------------------------------------- artifact + cache
def test_artifact_round_trip_toy(rng, tmp_path):
    g = make_toy_resnet_graph()
    params = toy_params(g)
    x = rng.standard_normal((1, 16, 16, 8)).astype(np.float32)
    qm = quantize.calibrate(g, params, x, executor.run_float)
    xq = quantize.quantize_to(x, qm.f_a["data"])
    s = pathsearch.search(g, ZU2)
    rep = validate.artifact_round_trip(g, qm, xq, s, ZU2,
                                       str(tmp_path / "toy.npz"))
    assert rep.bit_exact, rep.max_abs_diff


def test_plan_cache_hits_and_distinguishes():
    g = make_toy_resnet_graph()
    cache = asm.PlanCache()
    s = pathsearch.search(g, ZU2)
    a1, hit1 = cache.get_or_compile(g, s, ZU2)
    a2, hit2 = cache.get_or_compile(g, s, ZU2)
    assert not hit1 and hit2 and a1 is a2
    naive = pathsearch.naive(g, ZU2)
    _, hit3 = cache.get_or_compile(g, naive, ZU2)
    assert not hit3                 # different strategy -> different plan
    assert len(cache) == 2 and cache.hits == 1 and cache.misses == 2


@pytest.mark.parametrize("model,img", [("vgg16", 64), ("resnet50", 64),
                                       ("googlenet", 64)])
def test_paper_models_planned_and_checked(model, img):
    """Acceptance: addressed plan, clean hazard check, strict DDR reuse win."""
    g = build(model, img=img, num_classes=10)
    from repro.core import partition
    dv = partition.device_of(g, "paper")
    s = pathsearch.search(g, ZU2, device_of=dv)
    art = asm.compile_strategy(g, s, ZU2)   # hazard check runs inside
    for i in art.instrs:
        if i.opcode in ("LOAD", "SAVE"):
            assert i.bank >= 0 and i.ddr_addr >= 0, i
    assert art.peak_ddr_bytes < art.mem_summary["no_reuse_bytes"]
    assert art.reuse_factor > 1.0


@pytest.mark.parametrize("model,img", [("vgg16", 32), ("resnet50", 32),
                                       ("googlenet", 64)])
def test_paper_models_artifact_round_trip(model, img, rng, tmp_path):
    """Acceptance: save -> load -> execute is bit-exact with the in-memory
    plan (and with the unfused oracle) for the paper's benchmarks."""
    g = build(model, img=img, num_classes=10)
    params = init_params(g)
    x = rng.standard_normal(g.shape("data")).astype(np.float32)
    qm = quantize.calibrate(g, params, x, executor.run_float)
    xq = quantize.quantize_to(x, qm.f_a["data"])
    s = pathsearch.search(g, ZU2)
    rep = validate.artifact_round_trip(g, qm, xq, s, ZU2,
                                       str(tmp_path / f"{model}.npz"))
    assert rep.bit_exact, rep.max_abs_diff
