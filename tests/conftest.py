import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def make_toy_resnet_graph(size=16, c=8):
    """Small branchy graph exercising conv/pool/eltwise/fc + frontend passes."""
    from repro.core import frontend
    from repro.core.xgraph import XGraph

    g = XGraph("toy")
    g.input("data", (1, size, size, c))
    g.add("conv", "c1", ("data",), oc=16, kernel=(3, 3), stride=(1, 1), pad="same")
    g.add("relu", "r1", ("c1",))
    g.add("conv", "c2a", ("r1",), oc=16, kernel=(3, 3), pad="same")
    g.add("relu", "r2a", ("c2a",))
    g.add("conv", "c2b", ("r2a",), oc=16, kernel=(3, 3), pad="same")
    g.add("conv", "c2s", ("r1",), oc=16, kernel=(1, 1), pad="same")
    g.add("eltwise_add", "add1", ("c2b", "c2s"))
    g.add("relu", "r3", ("add1",))
    g.add("conv", "c3", ("r3",), oc=16, kernel=(3, 3), pad="valid")
    g.add("maxpool", "p1", ("c3",), kernel=(2, 2), stride=(2, 2))
    g.add("fc", "fc1", ("p1",), oc=10)
    return frontend.lower(g)


def toy_params(g, seed=0):
    from repro.cnn import init_params

    return init_params(g, seed=seed)
