"""Multi-tenant serving: routing bit-exactness, DDR partitioning, admission
control, and the bounded shared plan cache (ISSUE 7 satellites)."""
import numpy as np
import pytest

from repro import asm
from repro.core import executor, pathsearch, quantize
from repro.hw import ZU2
from repro.obs.metrics import REGISTRY
from repro.runtime import AdmissionError, MultiServer, Session
from tests.conftest import make_toy_resnet_graph, toy_params


def _model(seed, cache=None):
    """One compiled toy model; ``seed`` differentiates the weights."""
    g = make_toy_resnet_graph()
    params = toy_params(g, seed=seed)
    x = np.random.default_rng(seed).standard_normal(
        g.shape("data")).astype(np.float32)
    qm = quantize.calibrate(g, params, x, executor.run_float)
    s = pathsearch.search(g, ZU2)
    return Session(g, s, ZU2, qm,
                   cache=cache if cache is not None else asm.PlanCache())


@pytest.fixture(scope="module")
def two_models():
    return _model(0), _model(1)


# ----------------------------------------------------------------- routing
def test_routing_is_bit_exact_per_tenant(two_models):
    """Interleaved streams for two co-resident models must produce exactly
    what each model's own session produces in isolation."""
    sa, sb = two_models
    g = sa.graph
    rng = np.random.default_rng(3)
    xs = rng.integers(-128, 127, (6,) + tuple(g.shape("data")[1:]), np.int8)
    with MultiServer() as ms:
        ms.add_model("a", sa, slo="gold", max_latency_s=1e-4, warmup=False)
        ms.add_model("b", sb, slo="silver", max_latency_s=1e-4, warmup=False)
        futs = [(name, x, ms.submit(name, x))
                for x in xs for name in ("a", "b")]
        for name, x, fut in futs:
            want = (sa if name == "a" else sb).run(x)
            got = fut.result(timeout=30)
            for k in want:
                np.testing.assert_array_equal(got[k], want[k])
    st = ms.stats()
    assert st["models"]["a"]["n_served"] == len(xs)
    assert st["models"]["b"]["n_served"] == len(xs)
    assert st["slo"] == {"a": "gold", "b": "silver"}


# ---------------------------------------------------------- DDR partitioning
def test_ddr_partition_is_disjoint_and_bounded(two_models):
    sa, sb = two_models
    with MultiServer() as ms:
        ms.add_model("a", sa, warmup=False)
        ms.add_model("b", sb, warmup=False)
        parts = ms.ddr_partition()
    assert parts[0]["base"] == 0
    assert parts[1]["base"] == parts[0]["bytes"]       # disjoint regions
    used = sum(p["bytes"] for p in parts)
    assert used <= ZU2.ddr_bytes
    assert ms.stats()["ddr_used_bytes"] == used


def test_add_model_refused_when_ddr_budget_exhausted(two_models):
    sa, sb = two_models
    budget = int(sa.artifact.peak_ddr_bytes * 1.5)     # fits one, not two
    with MultiServer(ddr_budget_bytes=budget) as ms:
        ms.add_model("a", sa, warmup=False)
        with pytest.raises(MemoryError, match="DDR"):
            ms.add_model("b", sb, warmup=False)
        assert ms.models() == ["a"]
        # removing the resident model frees its region
        ms.remove_model("a")
        ms.add_model("b", sb, warmup=False)
        assert ms.ddr_partition()[0]["base"] == 0


def test_device_and_name_conflicts_rejected(two_models):
    sa, _ = two_models
    with MultiServer() as ms:
        ms.add_model("a", sa, warmup=False)
        with pytest.raises(ValueError, match="already registered"):
            ms.add_model("a", sa, warmup=False)
        with pytest.raises(ValueError, match="unknown SLO"):
            ms.add_model("c", sa, slo="platinum", warmup=False)


# --------------------------------------------------------- admission control
def test_admission_control_sheds_load(two_models):
    sa, _ = two_models
    g = sa.graph
    x = np.zeros(tuple(g.shape("data")[1:]), np.int8)
    with MultiServer() as ms:
        ms.add_model("a", sa, max_queue=0, warmup=False)
        with pytest.raises(AdmissionError):
            ms.submit("a", x)
    assert REGISTRY.get("serve.rejected{model=a}").value >= 1.0


def test_rejection_feeds_events_and_flight_recorder(two_models):
    """ISSUE 8: shedding load writes an ``admission.reject`` event, a
    ``rejected`` flight record, and an ``admission_rejection`` forensic
    dump carrying the tenant's static context."""
    from repro.obs.events import EventLog
    from repro.obs.flight import FlightRecorder

    sa, _ = two_models
    g = sa.graph
    x = np.zeros(tuple(g.shape("data")[1:]), np.int8)
    events = EventLog()
    flight = FlightRecorder(capacity=16, events=events)
    with MultiServer(flight=flight, events=events) as ms:
        ms.add_model("a", sa, max_queue=0, warmup=False)
        with pytest.raises(AdmissionError):
            ms.submit("a", x)
    assert [e.kind for e in events.records(kind="admission")] \
        == ["admission.reject"]
    rec = flight.records()[-1]
    assert rec.status == "rejected" and rec.tenant == "a"
    dump = flight.dumps()[-1]
    assert dump["reason"] == "admission_rejection"
    assert dump["context"]["a"]["slo_class"] == "best_effort"
    assert dump["context"]["a"]["tiles"] == sa.tile_summary()


def test_stats_use_label_index_and_expose_burn(two_models):
    """ISSUE 8 satellite: per-tenant stats come from
    ``MetricsRegistry.labelled`` (no hand-formatted name lookups) and carry
    live burn rates for SLO-targeted tenants."""
    sa, sb = two_models
    g = sa.graph
    x = np.zeros(tuple(g.shape("data")[1:]), np.int8)
    with MultiServer(burn_kw=dict(fast_window_s=1.0, slow_window_s=2.0,
                                  min_samples=4)) as ms:
        ms.add_model("a", sa, slo="gold", warmup=False)
        ms.add_model("b", sb, slo="best_effort", warmup=False)
        before = ms.stats()["requests"]["a"]
        [f.result(timeout=30) for f in [ms.submit("a", x) for _ in range(3)]]
        st = ms.stats()
    # the shared registry accumulates across tests: assert the delta
    assert st["requests"]["a"] >= before + 3.0
    assert set(st["requests"]) == set(st["rejected"]) == {"a", "b"}
    assert st["burn"]["b"] is None                 # no target, no tracker
    assert set(st["burn"]["a"]) == {"fast", "slow", "n_fast", "n_slow"}
    assert st["burn"]["a"]["n_fast"] >= 3
    # the burn gauges are scrapeable with model+class+window labels
    g_fast = REGISTRY.get("slo.burn_rate{class=gold,model=a,window=fast}")
    assert g_fast is not None


def test_gold_slo_violation_alerts_and_dumps(two_models):
    """A gold tenant with an unattainable target must burn its error budget,
    fire the burn-rate alert, and freeze a slo_violation flight dump whose
    records carry the offending requests' queue/execute split."""
    from repro.obs.events import EventLog
    from repro.obs.flight import FlightRecorder

    sa, _ = two_models
    g = sa.graph
    x = np.zeros(tuple(g.shape("data")[1:]), np.int8)
    events = EventLog()
    flight = FlightRecorder(capacity=64, events=events)
    with MultiServer(flight=flight, events=events,
                     burn_kw=dict(fast_window_s=30.0, slow_window_s=60.0,
                                  min_samples=4, cooldown_s=0.0)) as ms:
        # 1e-6 ms p99 is unattainable: every request violates
        ms.add_model("a", sa, slo="gold", target_p99_ms=1e-6, warmup=False)
        [f.result(timeout=30) for f in [ms.submit("a", x) for _ in range(8)]]
    alerts = events.records(kind="slo.alert")
    assert alerts and alerts[0].fields["model"] == "a"
    assert alerts[0].fields["fast_burn"] >= 2.0
    dumps = [d for d in flight.dumps() if d["reason"] == "slo_violation"]
    assert dumps
    ok = [r for r in dumps[-1]["records"] if r["status"] == "ok"]
    assert ok and all(r["queue_wait_s"] >= 0 and r["execute_s"] > 0
                      and r["batch_size"] >= 1 for r in ok)
    assert REGISTRY.get("slo.alerts{class=gold,model=a}").value >= 1.0


# -------------------------------------------------- bounded shared plan cache
def test_plan_cache_lru_eviction_across_three_models():
    """A shared plan cache bounded to 2 entries serving 3 models must evict
    LRU artifacts and count the evictions into the metrics registry."""
    before = (REGISTRY.get("plan_cache.evictions").value
              if REGISTRY.get("plan_cache.evictions") else 0.0)
    cache = asm.PlanCache(max_entries=2)
    sessions = [_model(seed, cache=cache) for seed in (0, 1, 2)]
    assert len(cache) == 2
    assert cache.evictions == 1
    assert REGISTRY.get("plan_cache.evictions").value == before + 1
    # model 0 was evicted (LRU): rebuilding it is a miss; model 2 is a hit
    s2 = Session(sessions[2].graph, sessions[2].artifact, ZU2,
                 sessions[2].qm, cache=cache)
    assert s2.cache_hit
    misses = cache.misses
    s0 = Session(sessions[0].graph, sessions[0].artifact, ZU2,
                 sessions[0].qm, cache=cache)
    assert not s0.cache_hit and cache.misses == misses + 1


def test_session_exposes_cache_max_entries():
    cache = asm.PlanCache()
    s = _model(0)
    sess = Session(s.graph, s.artifact, ZU2, s.qm, cache=cache,
                   cache_max_entries=3)
    assert cache.max_entries == 3
    with pytest.raises(ValueError):
        cache.max_entries = 0


def test_multiserver_rebounds_shared_plan_cache():
    old = asm.PLAN_CACHE.max_entries
    try:
        MultiServer(plan_cache_max_entries=5)
        assert asm.PLAN_CACHE.max_entries == 5
    finally:
        asm.PLAN_CACHE.max_entries = old
