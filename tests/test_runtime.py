"""Runtime supporter (ISSUE 3 tentpole): plan-cached sessions, the dynamic
batching queue, batch-dim execution bit-exactness, executor input validation,
and the hazard-audited cross-request pipeline schedule."""
import dataclasses
import threading
import time

import numpy as np
import pytest

from repro import asm
from repro.cnn import build, init_params
from repro.core import executor, pathsearch, quantize, simulator
from repro.core.executor import Int8Executor
from repro.hw import ZU2
from repro.runtime import (BatcherClosed, DynamicBatcher, Session,
                           pipeline_report, pipeline_stream)
from tests.conftest import make_toy_resnet_graph, toy_params


@pytest.fixture(scope="module")
def toy_compiled():
    g = make_toy_resnet_graph()
    params = toy_params(g)
    rng = np.random.default_rng(0)
    x = rng.standard_normal(g.shape("data")).astype(np.float32)
    qm = quantize.calibrate(g, params, x, executor.run_float)
    s = pathsearch.search(g, ZU2)
    return g, qm, s


# ------------------------------------------------------------- plan cache
def test_plan_cache_counters_and_lru_eviction(toy_compiled):
    g, qm, s = toy_compiled
    cache = asm.PlanCache(maxsize=2)
    a1, hit = cache.get_or_compile(g, s, ZU2, qm=qm)
    assert not hit and cache.misses == 1 and cache.hits == 0
    _, hit = cache.get_or_compile(g, s, ZU2, qm=qm)
    assert hit and cache.hits == 1
    # two more keys evict the LRU entry (capacity 2)
    naive = pathsearch.naive(g, ZU2)
    cache.get_or_compile(g, naive, ZU2, qm=qm)      # key 2; key 1 refreshed
    _, hit = cache.get_or_compile(g, s, ZU2, qm=qm)  # key 1 still resident
    assert hit
    greedy = pathsearch.greedy(g, ZU2)
    cache.get_or_compile(g, greedy, ZU2, qm=qm)     # key 3 evicts naive (LRU)
    assert len(cache) == 2
    _, hit = cache.get_or_compile(g, naive, ZU2, qm=qm)
    assert not hit and cache.misses == 4             # recompiled after evict


def test_plan_cache_signature_stable_across_save_load(toy_compiled, tmp_path):
    """A saved+loaded artifact must map to the SAME cache key as the
    in-memory compilation it came from (graph, strategy and quantization
    signatures all survive the npz round trip)."""
    g, qm, s = toy_compiled
    cache = asm.PlanCache()
    art, _ = cache.get_or_compile(g, s, ZU2, qm=qm)
    path = str(tmp_path / "sess.npz")
    asm.save_artifact(art, path)
    loaded = asm.load_artifact(path)
    g2 = loaded.rebuild_graph()
    qm2 = loaded.quantized_model()
    assert cache.key(g2, loaded, ZU2, qm2) == cache.key(g, s, ZU2, qm)
    # and therefore a session opened on the loaded artifact hits the cache
    cache.put(g2, loaded, ZU2, loaded, qm=qm2)
    _, hit = cache.get_or_compile(g2, loaded, ZU2, qm=qm2)
    assert hit


def test_session_from_artifact_seeds_cache(toy_compiled, tmp_path):
    g, qm, s = toy_compiled
    cache = asm.PlanCache()
    art, _ = cache.get_or_compile(g, s, ZU2, qm=qm)
    path = str(tmp_path / "art.npz")
    asm.save_artifact(art, path)
    loaded = asm.load_artifact(path)
    misses_before = cache.misses
    sess = Session.from_artifact(loaded, cache=cache)
    assert cache.misses == misses_before      # seeded, not recompiled
    assert sess.cache_hit
    out = sess.run(np.zeros((1,) + tuple(g.shape("data")[1:]), np.int8))
    assert set(out) == set(sess.outputs)


# ------------------------------------------------------- dynamic batching
def test_batcher_orders_and_caps_batches():
    calls = []

    def run_batch(xs):
        calls.append(len(xs))
        return [x * 10 for x in xs]

    with DynamicBatcher(run_batch, max_batch=4, max_latency_s=0.05) as b:
        futs = [b.submit(i) for i in range(10)]
        results = [f.result(timeout=10) for f in futs]
    assert results == [i * 10 for i in range(10)]    # per-request mapping
    assert max(calls) <= 4
    assert sum(calls) == 10
    assert sum(b.batch_sizes.values()) == len(calls)
    assert b.n_served == 10


def test_batcher_max_latency_flushes_partial_batch():
    done = threading.Event()

    def run_batch(xs):
        done.set()
        return list(xs)

    b = DynamicBatcher(run_batch, max_batch=64, max_latency_s=0.05)
    try:
        t0 = time.monotonic()
        fut = b.submit("x")
        assert fut.result(timeout=10) == "x"
        waited = time.monotonic() - t0
        # flushed by the latency knob, far below any full-batch horizon
        assert done.is_set() and waited < 5.0
        assert b.batch_sizes.get(1) == 1
    finally:
        b.close()


def test_batcher_empty_queue_shutdown_and_submit_after_close():
    b = DynamicBatcher(lambda xs: list(xs), max_batch=8, max_latency_s=10.0)
    t0 = time.monotonic()
    b.close()                                  # nothing queued: returns fast
    assert time.monotonic() - t0 < 5.0
    assert not b._worker.is_alive()
    with pytest.raises(BatcherClosed):
        b.submit(1)


def test_batcher_close_drains_pending_requests():
    def slow_batch(xs):
        time.sleep(0.01)
        return list(xs)

    b = DynamicBatcher(slow_batch, max_batch=2, max_latency_s=5.0)
    futs = [b.submit(i) for i in range(5)]
    b.close()                                  # flushes the queue first
    assert [f.result(timeout=1) for f in futs] == list(range(5))


def test_batcher_propagates_executor_failure():
    def boom(xs):
        raise RuntimeError("kernel exploded")

    with DynamicBatcher(boom, max_batch=2, max_latency_s=0.01) as b:
        fut = b.submit(1)
        with pytest.raises(RuntimeError, match="kernel exploded"):
            fut.result(timeout=10)


# ------------------------------------------- batched execution bit-exactness
def test_session_batched_run_bit_exact_vs_per_request(toy_compiled):
    g, qm, s = toy_compiled
    sess = Session(g, s, ZU2, qm, backend="ref", cache=asm.PlanCache())
    rng = np.random.default_rng(3)
    reqs = [rng.integers(-128, 128, g.shape("data")).astype(np.int8)
            for _ in range(5)]
    batched = sess.run_batch(reqs, pad_to=8)   # exercises zero-padding too
    oracle = Int8Executor(g, qm, strategy=None, backend="ref")
    for x, got in zip(reqs, batched):
        ref = oracle(x)
        for k in sess.outputs:
            assert np.array_equal(ref[k], got[k]), k


def test_pallas_backend_batch_dim(rng):
    """One Pallas launch serves N stacked images bit-exactly (the grid's
    leading axis is the batch)."""
    from repro.core import frontend
    from repro.core.xgraph import XGraph

    g = XGraph("b")
    g.input("data", (1, 8, 8, 4))
    g.add("conv", "c1", ("data",), oc=8, kernel=(3, 3), pad="same", relu="relu")
    g.add("maxpool", "p", ("c1",), kernel=(2, 2), stride=(2, 2))
    frontend.lower(g)
    params = init_params(g)
    x = rng.standard_normal(g.shape("data")).astype(np.float32)
    qm = quantize.calibrate(g, params, x, executor.run_float)
    s = pathsearch.Strategy(groups=[["c1", "p"]], horizontal=[], cost=0.0)
    xb = rng.integers(-128, 128, (3, 8, 8, 4)).astype(np.int8)
    got = Int8Executor(g, qm, strategy=s, backend="pallas")(xb)
    ref = Int8Executor(g, qm, strategy=None, backend="ref")
    for i in range(3):
        one = ref(xb[i:i + 1])
        assert np.array_equal(one["p"], got["p"][i:i + 1])


# ------------------------------------------------------- input validation
def test_executor_input_validation(toy_compiled):
    g, qm, s = toy_compiled
    ex = Int8Executor(g, qm, strategy=s, backend="ref")
    shape = g.shape("data")
    with pytest.raises(ValueError, match="int8"):
        ex(np.zeros(shape, np.float32))
    with pytest.raises(ValueError, match="rank-4"):
        ex(np.zeros(shape[1:], np.int8))
    with pytest.raises(ValueError, match="extents"):
        ex(np.zeros((1, shape[1] + 2, shape[2], shape[3]), np.int8))
    ex(np.zeros((2,) + tuple(shape[1:]), np.int8))   # any batch is fine


# -------------------------------------------------- cross-request schedule
def test_pipeline_stream_is_hazard_free(toy_compiled):
    g, qm, s = toy_compiled
    art, _ = asm.PLAN_CACHE.get_or_compile(g, s, ZU2)
    for slots in (2, 3):
        stream = pipeline_stream(art, 6, ddr_slots=slots)
        assert len(stream) == 6 * len(art.instrs)
        simulator.check(stream)                    # raises on any hazard
    # the un-interleaved (request-major) stream must be clean too
    simulator.check(pipeline_stream(art, 4, interleave=False))


def test_pipeline_report_utilization_and_overlap(toy_compiled):
    g, qm, s = toy_compiled
    art, _ = asm.PLAN_CACHE.get_or_compile(g, s, ZU2)
    rep = pipeline_report(art, 6, ddr_slots=4)
    util = rep.utilization()
    assert set(util) == set(rep.busy_cycles)
    assert all(0.0 <= u <= 1.0 for u in util.values())
    assert 0.0 < rep.utilization(rep.bottleneck) <= 1.0
    # pipelining never loses to strictly sequential back-to-back execution
    assert rep.total_cycles <= rep.sequential_cycles
    assert len(rep.request_windows) == 6
    starts = [s0 for s0, _ in rep.request_windows]
    assert starts == sorted(starts)
    assert rep.n_instructions == 6 * len(art.instrs)
    # per-engine start/end windows cover the whole pipelined stream
    from repro.core.isa import ENGINES
    assert set(rep.engine_timeline) == set(ENGINES)
    assert sum(len(v) for v in rep.engine_timeline.values()) == \
        rep.n_instructions
    for wins in rep.engine_timeline.values():   # one engine: no overlap
        assert all(a[1] <= b[0] for a, b in zip(wins, wins[1:]))


def test_cross_request_bank_audit_mechanism():
    """pipeline_report re-keys the bank audit on base group ids because the
    per-request group renumbering would hide cross-request collisions.
    Hand-built stream: request 1's LOAD streams into bank 0 while request 0's
    compute is still reading it — invisible with renumbered gids, flagged
    once the audit sees the shared physical bank."""
    from repro.core.isa import Instr

    def req(off, gid, tile_off, load_deps):
        return [
            Instr(off + 0, "DDR_RD", "LOAD", 10, load_deps, bank=0,
                  group_id=gid, tile=tile_off),
            Instr(off + 1, "CONV", "CONV", 1000, (off + 0,),
                  group_id=gid, tile=tile_off),
            Instr(off + 2, "DDR_WR", "SAVE", 10, (off + 1,), bank=0,
                  group_id=gid, tile=tile_off),
        ]

    # r1's LOAD has no dep on r0's consumer -> starts at cycle 10 while r0's
    # CONV occupies [10, 1010) on the same in-bank
    broken = req(0, 0, 0, ()) + req(3, 1, 1, ())
    _, times = simulator.run_times(broken)
    renumbered = simulator.bank_hazards(broken, times)
    assert renumbered == []                      # per-request gids: blind
    shared = [dataclasses.replace(i, group_id=0) for i in broken]
    flagged = simulator.bank_hazards(shared, times)
    assert flagged and "in-bank hazard" in flagged[0]
    # with the ping/pong continuation dep the schedule threads, it is clean
    fixed = req(0, 0, 0, ()) + req(3, 1, 1, (1,))
    _, times = simulator.run_times(fixed)
    shared = [dataclasses.replace(i, group_id=0) for i in fixed]
    assert simulator.bank_hazards(shared, times) == []


def test_pipeline_without_cross_deps_is_caught_by_oracle():
    """Deliberate-hazard case: strip the cross-request dependency bits
    before the dispatcher merge and the memory-hazard oracle must flag the
    resulting DDR collisions — i.e. the bits pipeline_stream threads are
    load-bearing, not decorative."""
    import dataclasses

    from repro.core import partition
    from repro.runtime.schedule import _interleave

    g = build("vgg16", img=32, num_classes=10)
    dv = partition.device_of(g, "paper")
    s = pathsearch.search(g, ZU2, device_of=dv)
    art, _ = asm.PLAN_CACHE.get_or_compile(g, s, ZU2)
    n_base = len(art.instrs)
    raw = pipeline_stream(art, 6, ddr_slots=2, interleave=False)
    stripped = [dataclasses.replace(x, deps=tuple(d for d in x.deps
                                                  if abs(x.iid - d) < n_base))
                for x in raw]
    with pytest.raises(simulator.MemoryHazardError):
        simulator.check(_interleave(stripped, n_base))


def test_pipeline_overlaps_requests_on_vgg():
    """LOAD of request i+1 must overlap compute of request i: the modeled
    pipelined makespan beats sequential, and adjacent request windows
    intersect."""
    g = build("vgg16", img=32, num_classes=10)
    from repro.core import partition
    dv = partition.device_of(g, "paper")
    s = pathsearch.search(g, ZU2, device_of=dv)
    art, _ = asm.PLAN_CACHE.get_or_compile(g, s, ZU2)
    rep = pipeline_report(art, 6, ddr_slots=4)
    assert rep.modeled_speedup > 1.05, rep.modeled_speedup
    w = rep.request_windows
    assert all(w[i + 1][0] < w[i][1] for i in range(len(w) - 1)), w


def test_session_pipeline_report_and_stats(toy_compiled):
    g, qm, s = toy_compiled
    sess = Session(g, s, ZU2, qm, backend="ref", cache=asm.PlanCache())
    rep = sess.pipeline_report(3)
    assert rep.n_requests == 3
    sess.run(np.zeros((1,) + tuple(g.shape("data")[1:]), np.int8))
    st = sess.stats()
    assert st["images_served"] == 1 and st["n_runs"] == 1
    assert 0.0 <= st["fused_coverage"] <= 1.0


# ------------------------------------------------------------- end to end
def test_server_serves_bit_exact_with_batching(toy_compiled):
    g, qm, s = toy_compiled
    sess = Session(g, s, ZU2, qm, backend="ref", cache=asm.PlanCache())
    rng = np.random.default_rng(7)
    reqs = [rng.integers(-128, 128, g.shape("data")).astype(np.int8)
            for _ in range(6)]
    with sess.serve(max_batch=4, max_latency_s=0.02) as server:
        outs = [f.result(timeout=300)
                for f in [server.submit(x) for x in reqs]]
        stats = server.stats()
    assert stats["n_served"] == 6
    assert sum(k * v for k, v in stats["batch_histogram"].items()) == 6
    oracle = Int8Executor(g, qm, strategy=None, backend="ref")
    for x, got in zip(reqs, outs):
        ref = oracle(x)
        for k in sess.outputs:
            assert np.array_equal(ref[k], got[k]), k


# ------------------------------------------------- pin_input planner mode
def test_pin_input_removes_input_recycling_and_guards():
    """With pin_input the network input's DDR region leaves the reuse pool:
    nothing recycles it, the cross-request pre-load guard count drops to
    zero, and the modeled overlap never regresses."""
    g = build("vgg16", img=32, num_classes=10)
    s = pathsearch.search(g, ZU2)
    cache = asm.PlanCache()
    art, _ = cache.get_or_compile(g, s, ZU2)
    artp, hit = cache.get_or_compile(g, s, ZU2, pin_input=True)
    assert not hit                       # pin_input is part of the cache key
    assert artp.pin_input and not art.pin_input

    rep = pipeline_report(art, 4, ddr_slots=2)
    repp = pipeline_report(artp, 4, ddr_slots=2)
    assert rep.n_preload_guards > 0      # fc output recycles the input region
    assert repp.n_preload_guards == 0
    assert repp.pin_input and not rep.pin_input
    assert repp.overlap >= rep.overlap - 1e-9


def test_pin_input_round_trips_through_artifact(toy_compiled, tmp_path):
    g, qm, s = toy_compiled
    cache = asm.PlanCache()
    art, _ = cache.get_or_compile(g, s, ZU2, qm=qm, pin_input=True)
    path = str(tmp_path / "pinned.npz")
    asm.save_artifact(art, path)
    loaded = asm.load_artifact(path)
    assert loaded.pin_input
    # a session opened on the loaded artifact re-keys identically (pin_input
    # inherited from mem_summary) and hits the seeded cache
    sess = Session.from_artifact(loaded, cache=asm.PlanCache())
    assert sess.cache_hit and sess.stats()["pin_input"]


# ------------------------------------------------ latency-SLO batch sizing
def _slo_server(tmp_session, **kw):
    return tmp_session.serve(max_batch=8, max_latency_s=1e-3, warmup=False,
                             **kw)


def test_server_slo_shrinks_effective_batch(toy_compiled):
    g, qm, s = toy_compiled
    sess = Session(g, s, ZU2, qm, backend="ref", cache=asm.PlanCache())
    x = np.zeros(tuple(g.shape("data")), np.int8)
    # an unreachable SLO (0 ms) must walk the cap down the allowed ladder
    with _slo_server(sess, target_p99_ms=0.0) as server:
        for _ in range(4):               # several flushes -> several adjusts
            futs = [server.submit(x) for _ in range(8)]
            [f.result(timeout=60) for f in futs]
        stats = server.stats()
    assert stats["effective_max_batch"] == 1
    assert stats["slo_shrinks"] >= 3
    assert stats["target_p99_ms"] == 0.0


def test_server_slo_regrows_when_latency_clears(toy_compiled):
    g, qm, s = toy_compiled
    sess = Session(g, s, ZU2, qm, backend="ref", cache=asm.PlanCache())
    x = np.zeros(tuple(g.shape("data")), np.int8)
    with _slo_server(sess, target_p99_ms=1e9) as server:
        server._batcher.set_max_batch(1)     # pretend a past SLO violation
        for _ in range(3):
            futs = [server.submit(x) for _ in range(8)]
            [f.result(timeout=60) for f in futs]
        stats = server.stats()
    assert stats["effective_max_batch"] == 8  # fully recovered to max_batch
    assert stats["slo_grows"] >= 1
    assert stats["slo_shrinks"] == 0


def test_server_without_slo_keeps_static_cap(toy_compiled):
    g, qm, s = toy_compiled
    sess = Session(g, s, ZU2, qm, backend="ref", cache=asm.PlanCache())
    x = np.zeros(tuple(g.shape("data")), np.int8)
    with _slo_server(sess) as server:
        futs = [server.submit(x) for _ in range(8)]
        [f.result(timeout=60) for f in futs]
        stats = server.stats()
    assert stats["effective_max_batch"] == 8
    assert stats["slo_shrinks"] == 0 and stats["slo_grows"] == 0


def test_batcher_set_max_batch_validates():
    b = DynamicBatcher(lambda xs: list(xs), max_batch=4)
    try:
        with pytest.raises(ValueError):
            b.set_max_batch(0)
        b.set_max_batch(2)
        assert b.max_batch == 2
    finally:
        b.close()
